"""Pallas TPU kernel layer — the serving hot path on TPU.

Every op ships in three dispatch tiers (ref / interpret / compiled)
sharing one contract; see ``repro.kernels.dispatch`` and the kernels
section of docs/architecture.md.  Kernels exist ONLY for compute
hot-spots the paper itself optimizes (the corpus scan, the
per-utterance probe, the embedding bag).

Op re-exports are lazy (PEP 562): importing the package (as the core
serving modules do for the dispatch helpers) must not pull the Pallas
machinery onto a ref-tier-only process.  The probe ops are NOT
re-exported at package level — ``cache_probe`` would collide with the
subpackage of the same name (once the subpackage is imported anywhere,
the import system binds it as a package attribute and shadows any
function export); import them from ``repro.kernels.cache_probe.ops``.
"""

from repro.kernels import dispatch  # noqa: F401

__all__ = ["dispatch", "knn_search"]


def __getattr__(name):
    if name == "knn_search":
        from repro.kernels.knn.ops import knn_search as fn
        globals()[name] = fn
        return fn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
