from repro.kernels.knn.ops import knn_search  # noqa: F401
