"""Pipelined fused kNN corpus-scan kernel (see ``.ops``)."""

from repro.kernels.knn.ops import knn_search  # noqa: F401
