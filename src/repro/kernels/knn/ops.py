"""jit'd public wrapper for the fused kNN Pallas kernel.

Handles padding (corpus rows to the tile multiple, feature dim to the lane
multiple, batch to the sublane multiple — all score-preserving zero pads),
backend dispatch (interpret mode off-TPU), and the cross-tile merge.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.knn.knn import knn_tile_topk

LANE = 128
SUBLANE = 8


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def knn_search(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int,
               tile_n: int = 1024, interpret: bool | None = None):
    """Top-k MIPS over the corpus. Returns (scores (B,k), ids (B,k)).

    docs: (N, D) unit-norm transformed embeddings; doc_ids: (N,) int32
    (use arange for positional); queries: (B, D).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n_valid = docs.shape[0]
    tile_n = min(tile_n, max(SUBLANE, 1 << (n_valid - 1).bit_length()))
    k_eff = min(k, tile_n)

    docs_p = _pad_to(_pad_to(docs, 1, LANE), 0, tile_n)
    q_p = _pad_to(_pad_to(queries, 1, LANE), 0, SUBLANE)
    b = queries.shape[0]

    vals, idx = knn_tile_topk(docs_p, q_p, k_eff, tile_n=tile_n,
                              n_valid=n_valid, interpret=interpret)
    tiles = vals.shape[0]
    vals = vals.transpose(1, 0, 2).reshape(q_p.shape[0], tiles * k_eff)
    idx = idx.transpose(1, 0, 2).reshape(q_p.shape[0], tiles * k_eff)

    top_s, pos = jax.lax.top_k(vals, k)
    top_i = jnp.take_along_axis(idx, pos, axis=1)
    return top_s[:b], doc_ids[top_i[:b]]
