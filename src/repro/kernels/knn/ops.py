"""jit'd public wrapper for the fused kNN Pallas kernels.

Handles backend dispatch (``repro.kernels.dispatch`` tiers: ref / interpret
/ compiled), quantized corpora (``repro.core.quant``: bf16 / int8 payloads
with an optional per-document f32 ``scale`` applied score-side, identically
in every tier), the native int8-MXU-dot tier (``int8_dot``: queries are
quantized per-row to int8 here, in the wrapper, so ref and kernel tiers
score the SAME payloads and stay bit-identical with each other), padding
(corpus rows to the tile multiple with sentinel id -1, feature dim to the
lane multiple, batch to the sublane multiple — all score-preserving), the
width-aware ``tile_n``/``k_eff`` autotuner (the VMEM budget is element-size
dependent AND double-buffered: the pipelined kernel keeps TWO tiles
resident, so an int8 tile still holds ~4x the documents of an fp32 tile
but every dtype's tile halves vs the single-buffered budget), and
sentinel-id hygiene: any -inf candidate (k > n_valid, fully-masked tiles)
reports id -1 — never a padded-row position clipped onto a real document.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import dispatch
from repro.kernels.knn.knn import NEG_INF, knn_fused_topk, knn_tile_topk

LANE = 128
SUBLANE = 8


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.lru_cache(maxsize=None)
def autotune_knn(n: int, d: int, b: int, k: int,
                 itemsize: int = 4) -> tuple[int, int]:
    """Pick (tile_n, k_eff) for a corpus of shape (n, d) and batch (b, k).

    tile_n: largest power of two (<= 4096, >= the sublane multiple, no
    larger than the padded corpus) whose VMEM working set fits a ~6 MB
    budget (half of VMEM).  The working set is sized for the
    double-buffered DMA pipeline: TWO resident corpus tiles at
    ``itemsize`` bytes/element (4 fp32, 2 bf16, 1 int8) plus their id and
    scale columns — tile t+1 streams in while tile t is scored — plus the
    resident f32 query block, the (b, k) carry pair, and the f32 merge
    candidate pool.  Narrower corpus elements buy bigger tiles: the
    streamed-tile term dominates at serving shapes, so tile_n roughly
    doubles at bf16 and again at int8 (and halves across the board vs the
    old single-buffered budget — the price of the prefetch overlap).
    k_eff is the per-tile candidate count of the two-stage scheme
    (min(k, tile_n)).
    """
    dp = d + (-d) % LANE
    bp = b + (-b) % SUBLANE
    cap = max(SUBLANE, 1 << max(n - 1, 1).bit_length())
    tile = min(4096, cap)
    budget = 6 * 2 ** 20

    def working_set(t: int) -> int:
        # 2 payload tiles + 2 (id, scale) column pairs; query block; carry
        # vals+ids; merge pool (vals, ids, col iota) over (b, k + t)
        return (2 * t * (itemsize * dp + 8)
                + 4 * bp * dp + 8 * bp * k + 12 * bp * (k + t))

    while tile > SUBLANE and working_set(tile) > budget:
        tile //= 2
    return tile, min(k, tile)


def _ref_search(docs, doc_ids, queries, k, scale=None, int8_dot=False):
    """Oracle tier: one masked (B, N) score matrix + stable top-k.

    Shares the scan contract's scoring rules: dequantize-first (payload
    cast to f32, f32 dot, per-document ``scale`` applied to the *scores*)
    or, under ``int8_dot``, the int8 x int8 -> int32 dot with both fp32
    scales applied score-side in the kernel's association order.
    """
    if int8_dot:
        qq = quant.quantize(queries, "int8")
        acc = jax.lax.dot_general(
            qq.data, docs, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
        scores = acc.astype(jnp.float32) * qq.scale[:, None]
    else:
        scores = queries.astype(jnp.float32) @ docs.astype(jnp.float32).T
    if scale is not None:
        scores = scores * scale.astype(jnp.float32)[None, :]
    scores = jnp.where(doc_ids[None, :] < 0, NEG_INF, scores)
    ids = doc_ids
    if k > scores.shape[1]:
        pad = k - scores.shape[1]
        scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=NEG_INF)
        ids = jnp.pad(ids, (0, pad), constant_values=-1)
    top_s, pos = jax.lax.top_k(scores, k)
    top_i = jnp.where(jnp.isneginf(top_s), -1, ids[pos])
    return top_s, top_i


@functools.partial(jax.jit, static_argnames=(
    "k", "tile_n", "interpret", "backend", "two_stage", "int8_dot"))
def knn_search(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int,
               tile_n: int | None = None, interpret: bool | None = None,
               backend: str | None = None, two_stage: bool = False,
               scale: jax.Array | None = None,
               int8_dot: bool | None = None):
    """Top-k MIPS over the corpus. Returns (scores (B, k), ids (B, k)).

    docs: (N, D) transformed embeddings — fp32, or a quantized payload
    (bf16 / int8 from ``repro.core.quant.quantize``) with ``scale`` its
    (N,) f32 per-document score multiplier; doc_ids: (N,) int32 with -1
    marking sentinel/padded rows (use arange for positional); queries:
    (B, D) f32.  Sentinel rows never win top-k; -inf result positions
    carry id -1.  ``backend``: a ``repro.kernels.dispatch`` tier (default:
    compiled on TPU, interpret elsewhere — an explicit kernel call never
    silently degrades to the jnp path; pass backend="ref" for the oracle).
    ``interpret`` is the legacy spelling of backend="interpret".
    ``two_stage`` opts out of the on-chip cross-tile merge (A/B baseline);
    both merge paths share the id-driven validity masking and the
    score-side scale rule.  ``int8_dot`` (None = the ``REPRO_INT8_DOT``
    policy) switches an int8 corpus to the native int8 MXU dot — queries
    quantized per-row here so every tier scores identical payloads;
    ignored on fp32/bf16 corpora.
    """
    if backend is None and interpret is not None:
        backend = "interpret" if interpret else "compiled"
    be = dispatch.resolve(backend, kernel=True)
    use_i8 = quant.resolve_int8_dot(int8_dot, docs.dtype)
    if be == "ref":
        return _ref_search(docs, doc_ids, queries, k, scale=scale,
                           int8_dot=use_i8)

    n, d = docs.shape
    b = queries.shape[0]
    itemsize = jnp.dtype(docs.dtype).itemsize
    if tile_n is None:
        tile_n, k_eff = autotune_knn(n, d, b, k, itemsize)
    else:
        tile_n = min(tile_n, max(SUBLANE, 1 << max(n - 1, 1).bit_length()))
        k_eff = min(k, tile_n)

    docs_p = _pad_to(_pad_to(docs, 1, LANE), 0, tile_n)
    ids_p = _pad_to(doc_ids.astype(jnp.int32), 0, tile_n, value=-1)
    if use_i8:
        # quantize queries ONCE here — kernel and ref tiers then share the
        # exact payload, keeping tier parity bit-for-bit under int8_dot
        qq = quant.quantize(queries, "int8")
        q_p = _pad_to(_pad_to(qq.data, 1, LANE), 0, SUBLANE)
        qscale_p = _pad_to(qq.scale, 0, SUBLANE, value=1.0)
    else:
        q_p = _pad_to(_pad_to(queries, 1, LANE), 0, SUBLANE)
        qscale_p = None
    scale_p = (None if scale is None else
               _pad_to(scale.astype(jnp.float32), 0, tile_n, value=1.0))
    interp = dispatch.interpret_flag(be)

    if not two_stage:
        vals, idx = knn_fused_topk(docs_p, ids_p, q_p, k, tile_n=tile_n,
                                   interpret=interp, scale=scale_p,
                                   q_scale=qscale_p, int8_dot=use_i8)
        return vals[:b], idx[:b]

    vals, idx = knn_tile_topk(docs_p, ids_p, q_p, k_eff, tile_n=tile_n,
                              interpret=interp, scale=scale_p,
                              q_scale=qscale_p, int8_dot=use_i8)
    tiles = vals.shape[0]
    assert tiles * k_eff >= k, (
        f"two-stage candidate pool {tiles}x{k_eff} < k={k}; "
        f"use the fused merge (two_stage=False)")
    vals = vals.transpose(1, 0, 2).reshape(q_p.shape[0], tiles * k_eff)
    idx = idx.transpose(1, 0, 2).reshape(q_p.shape[0], tiles * k_eff)

    top_s, pos = jax.lax.top_k(vals, k)
    top_i = jnp.take_along_axis(idx, pos, axis=1)
    # a fully-masked extraction emits an arbitrary position at a -inf value;
    # sentinel it instead of letting the id lookup alias a real document
    ids = jnp.where(jnp.isneginf(top_s), -1, ids_p[top_i])
    return top_s[:b], ids[:b]
