"""Pure-jnp oracle for the fused kNN kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_ref(docs: jax.Array, queries: jax.Array, k: int):
    """Exact top-k by inner product. Returns (scores (B,k) f32, idx (B,k) i32)."""
    scores = (queries.astype(jnp.float32) @ docs.astype(jnp.float32).T)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
