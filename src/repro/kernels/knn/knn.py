"""Fused corpus-scan + top-k Pallas TPU kernels — the paper's hot spot.

The exhaustive FAISS scan (paper Table 3, ~1 s / 216-query batch on a Xeon)
is re-thought for the TPU memory hierarchy:

  * stream the corpus through VMEM one (TILE_N, D) tile at a time,
  * scores = Q @ tile.T on the MXU (D is zero-padded to a lane multiple by
    the wrapper, which leaves inner products unchanged),
  * top-k extraction by iterative max-extract on the VPU, so the full (B, N)
    score matrix is NEVER materialized in HBM.

Two merge strategies:

  * ``knn_fused_topk`` — the serving kernel, rebuilt (ISSUE 5) as an
    explicitly *double-buffered DMA pipeline*: the corpus, ids, and scales
    stay in HBM (``memory_space=ANY``) and the kernel issues its own
    ``make_async_copy`` HBM->VMEM transfers into two scratch slots — tile
    t+1's ``(docs, ids, scale)`` copy is launched *before* tile t is
    scored, so data movement overlaps the MXU/VPU work instead of
    serializing with it.  The running global top-k is a (B, k) carry held
    in VMEM scratch across tiles: each tile's scores are merged against
    the carry in-register and only the final (B, k) answer is ever written
    to HBM.  The corpus is read exactly once and the candidate traffic of
    the two-stage scheme (O(tiles * B * k) rows through HBM plus a second
    launch to merge) disappears entirely.  A ``pl.CostEstimate`` sized
    from the quant-aware byte counts tells the scheduler the launch is
    bandwidth-bound.  Validity is data-driven — scores at sentinel rows
    (id < 0) are masked to -inf — so one kernel serves unpadded, padded,
    and device-sharded corpora, and extracted -inf candidates report id
    -1, never a clipped real id.
  * ``knn_tile_topk`` — the original two-stage scheme (per-tile top-k
    candidates to HBM, cross-tile ``lax.top_k`` merge in the wrapper), kept
    as the A/B baseline for ``kernel_bench`` and for the k > tile_n regime.
    Its tile stream rides the grid pipeline (which Mosaic double-buffers
    automatically) with the same ``pl.CostEstimate`` hints attached.

Arithmetic intensity of the scan is ~2*B flops per corpus byte, so for
serving batches (B <= 256 at fp32) the kernel is HBM-bandwidth bound; the
design goal is to stream at full bandwidth, which the single-pass pipelined
structure achieves.  Quantized corpora (``repro.core.quant``: bf16
payloads, or int8 payloads with an fp32 per-document scale) stream 2x / 4x
more documents per HBM byte.  Two scoring rules:

  * dequantize-first (the default, and the ref/parity tier): the payload is
    cast to f32 *in VMEM*, the dot runs in f32, and the per-document scale
    is applied score-side — every dispatch tier is rank-identical at a
    fixed dtype.
  * native int8 MXU dot (``int8_dot=True``, int8 corpora only): queries are
    quantized per-row to int8 by the wrapper and the dot runs int8 x int8
    with int32 accumulation (``preferred_element_type=jnp.int32``) — the
    MXU's native narrow mode — then both the per-query and per-document
    fp32 scales are applied score-side.  Rankings vs the fp32 corpus are
    gated at the established int8 floor (>= 0.90 rank overlap); ref and
    kernel tiers still agree exactly with *each other* because they share
    this rule bit for bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _masked_scores(q, docs, ids, scale, q_scale=None, *, int8_dot=False):
    """(B, TILE_N) scores with sentinel rows (id < 0) masked to -inf.

    Dequantize-first rule (default): ``docs`` (fp32 / bf16 / int8 payload)
    is cast to f32 before the dot (dequantization happens here, in VMEM)
    and ``scale`` — the (1, TILE_N) per-document f32 score multiplier,
    all-ones for unquantized corpora — is applied to the scores, matching
    the shared ``quant.scale_scores`` rule of the ref tier bit for bit.

    int8-MXU rule (``int8_dot``): ``q`` is an int8 payload with
    ``q_scale`` its (B, 1) f32 per-query multiplier; the dot runs int8 x
    int8 with int32 accumulation and both scales apply score-side, in a
    fixed association order — ``(f32(acc) * q_scale) * scale`` — shared
    with the ref tier so tiers agree bitwise.
    """
    if int8_dot:
        acc = jax.lax.dot_general(
            q, docs, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)          # (B, TILE_N) exact
        scores = (acc.astype(jnp.float32) * q_scale) * scale
    else:
        scores = jax.lax.dot_general(
            q.astype(jnp.float32), docs.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (B, TILE_N)
        scores = scores * scale
    return jnp.where(ids < 0, NEG_INF, scores)


def _merge_tile_into_carry(scores, ids, carry_v, carry_i, *, k: int):
    """Merge one tile's (B, TILE_N) scores into the (B, k) VMEM carry.

    candidate pool = running carry ++ this tile; carry columns come first,
    so equal scores resolve to the earliest corpus position — the same
    tie-break a stable global lax.top_k applies.
    """
    cand_v = jnp.concatenate([carry_v[...], scores], axis=1)
    cand_i = jnp.concatenate(
        [carry_i[...], jnp.broadcast_to(ids, scores.shape)], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)

    def extract(j, s):
        m = jnp.max(s, axis=1)                             # (B,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)        # (B,)
        hit = col == a[:, None]
        # one-hot reduce instead of a gather: id at the extracted column
        picked = jnp.sum(jnp.where(hit, cand_i, 0), axis=1).astype(jnp.int32)
        picked = jnp.where(m == NEG_INF, -1, picked)       # sentinel, not id
        carry_v[:, pl.dslice(j, 1)] = m[:, None]
        carry_i[:, pl.dslice(j, 1)] = picked[:, None]
        return jnp.where(hit, NEG_INF, s)

    jax.lax.fori_loop(0, k, extract, cand_v)


def _fused_kernel(q_ref, qscale_ref, docs_hbm, ids_hbm, scale_hbm,
                  out_vals_ref, out_idx_ref,
                  docs_buf, ids_buf, scale_buf, carry_v, carry_i,
                  docs_sem, ids_sem, scale_sem,
                  *, k: int, tile_n: int, tiles: int, int8_dot: bool):
    """Single launch: double-buffered HBM->VMEM tile pipeline + on-chip merge.

    The corpus operands live in HBM (``memory_space=ANY``); two VMEM
    scratch slots per operand hold the in-flight and the in-use tile.  Tile
    t+1's three DMAs start before tile t is scored, so the MXU never waits
    on HBM except for the very first tile (and the autotuner budgets VMEM
    for exactly these two resident tiles).
    """
    carry_v[...] = jnp.full(carry_v.shape, NEG_INF, jnp.float32)
    carry_i[...] = jnp.full(carry_i.shape, -1, jnp.int32)

    def tile_dmas(slot, t):
        return (
            pltpu.make_async_copy(
                docs_hbm.at[pl.ds(t * tile_n, tile_n)],
                docs_buf.at[slot], docs_sem.at[slot]),
            pltpu.make_async_copy(
                ids_hbm.at[pl.ds(t, 1)], ids_buf.at[slot], ids_sem.at[slot]),
            pltpu.make_async_copy(
                scale_hbm.at[pl.ds(t, 1)], scale_buf.at[slot],
                scale_sem.at[slot]),
        )

    for dma in tile_dmas(0, 0):                            # warm-up: tile 0
        dma.start()

    def step(t, _):
        cur = jax.lax.rem(t, 2)
        nxt = jax.lax.rem(t + 1, 2)

        @pl.when(t + 1 < tiles)
        def _prefetch():                                   # overlap t+1 copy
            for dma in tile_dmas(nxt, t + 1):
                dma.start()

        for dma in tile_dmas(cur, t):                      # tile t landed?
            dma.wait()

        scores = _masked_scores(
            q_ref[...], docs_buf[cur], ids_buf[cur], scale_buf[cur],
            qscale_ref[...], int8_dot=int8_dot)            # (B, TILE_N)
        _merge_tile_into_carry(scores, ids_buf[cur], carry_v, carry_i, k=k)
        return 0

    jax.lax.fori_loop(0, tiles, step, 0)
    out_vals_ref[...] = carry_v[...]
    out_idx_ref[...] = carry_i[...]


def _scan_cost(n: int, d: int, b: int, k: int, itemsize: int,
               int8_dot: bool) -> pl.CostEstimate:
    """Quant-aware cost hint: the scan streams the corpus payload once
    (``itemsize`` bytes/element — this is what bf16/int8 shrink), plus the
    int32 id and f32 scale columns, the resident query block, and the
    (B, k) answer; ~2*B*N*D flops (int8-MXU dots cost the same flop count
    at higher native throughput)."""
    q_item = 1 if int8_dot else 4
    return pl.CostEstimate(
        flops=2 * b * n * d,
        bytes_accessed=(n * (d * itemsize + 4 + 4)
                        + b * (d * q_item + 4) + b * k * 8),
        transcendentals=0,
    )


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret",
                                             "int8_dot"))
def knn_fused_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                   k: int, tile_n: int = 1024, interpret: bool = False,
                   scale: jax.Array | None = None,
                   q_scale: jax.Array | None = None, int8_dot: bool = False):
    """Single-launch exact top-k: double-buffered DMA scan, merge on chip.

    docs: (N, D) payload (fp32 / bf16 / int8) padded to a tile_n multiple
    and lane-aligned D; doc_ids: (N,) int32 with -1 on padded/sentinel
    rows; queries: (B, D) f32 — or the (B, D) int8 query payload when
    ``int8_dot`` (with ``q_scale`` its (B,) f32 per-query multiplier);
    scale: (N,) f32 per-document score multipliers (None for an
    unquantized corpus).  Returns (scores (B, k) f32 descending, ids
    (B, k) int32, -1 at -inf positions).
    """
    n, d = docs.shape
    b = queries.shape[0]
    assert n % tile_n == 0
    tiles = n // tile_n
    ids_2d = doc_ids.reshape(tiles, tile_n)
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    scale_2d = scale.astype(jnp.float32).reshape(tiles, tile_n)
    if q_scale is None:
        q_scale = jnp.ones((b,), jnp.float32)
    qscale_col = q_scale.astype(jnp.float32).reshape(b, 1)
    kernel = functools.partial(_fused_kernel, k=k, tile_n=tile_n,
                               tiles=tiles, int8_dot=int8_dot)
    itemsize = jnp.dtype(docs.dtype).itemsize
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),         # queries: resident
            pl.BlockSpec(memory_space=pltpu.VMEM),         # per-query scales
            pl.BlockSpec(memory_space=pltpu.ANY),          # corpus: HBM
            pl.BlockSpec(memory_space=pltpu.ANY),          # tile ids: HBM
            pl.BlockSpec(memory_space=pltpu.ANY),          # doc scales: HBM
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, tile_n, d), docs.dtype),        # double tile buf
            pltpu.VMEM((2, 1, tile_n), jnp.int32),         # double id buf
            pltpu.VMEM((2, 1, tile_n), jnp.float32),       # double scale buf
            pltpu.VMEM((b, k), jnp.float32),               # running top-k vals
            pltpu.VMEM((b, k), jnp.int32),                 # running top-k ids
            pltpu.SemaphoreType.DMA((2,)),                 # docs DMA sems
            pltpu.SemaphoreType.DMA((2,)),                 # ids DMA sems
            pltpu.SemaphoreType.DMA((2,)),                 # scale DMA sems
        ],
        cost_estimate=_scan_cost(n, d, b, k, itemsize, int8_dot),
        interpret=interpret,
    )(queries, qscale_col, docs, ids_2d, scale_2d)


def _knn_kernel(q_ref, qscale_ref, docs_ref, ids_ref, scale_ref, out_vals_ref,
                out_idx_ref, *, k: int, tile_n: int, int8_dot: bool):
    """One grid step: score one corpus tile against all queries; emit top-k."""
    tile = pl.program_id(0)
    q = q_ref[...]                      # (B, D) f32 — or int8 payload
    docs = docs_ref[...]                # (TILE_N, D) any dtype
    ids = ids_ref[...]                  # (1, TILE_N) int32
    scale = scale_ref[...]              # (1, TILE_N) f32
    # same data-driven validity as the fused kernel: sentinel rows (id < 0)
    # can never win a per-tile extraction, wherever they sit in the corpus
    scores = _masked_scores(q, docs, ids, scale, qscale_ref[...],
                            int8_dot=int8_dot)            # (B, TILE_N)
    base = tile * tile_n

    def body(j, s):
        m = jnp.max(s, axis=1)                         # (B,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)    # (B,)
        out_vals_ref[0, :, pl.dslice(j, 1)] = m[:, None]
        out_idx_ref[0, :, pl.dslice(j, 1)] = (base + a)[:, None]
        # knock out the extracted column per row
        hit = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == a[:, None]
        return jnp.where(hit, NEG_INF, s)

    jax.lax.fori_loop(0, k, body, scores)


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret",
                                             "int8_dot"))
def knn_tile_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                  k: int, tile_n: int = 1024, interpret: bool = False,
                  scale: jax.Array | None = None,
                  q_scale: jax.Array | None = None, int8_dot: bool = False):
    """Per-tile top-k candidates (two-stage scheme). docs: (N, D) payload
    (fp32 / bf16 / int8) padded to a tile_n multiple and lane-aligned D;
    doc_ids: (N,) int32 with -1 on sentinel/padded rows (masked to -inf,
    same contract as the fused kernel); queries: (B, D) f32 (int8 payload
    + ``q_scale`` under ``int8_dot``); scale: (N,) f32 per-document score
    multipliers or None. Returns (tiles, B, k) vals + idx; idx are
    *positions* in the padded corpus (a fully-masked extraction can emit
    any position at a -inf value — the wrapper must sentinel those on
    merge).  The tile stream rides the grid pipeline (auto double-buffered
    by Mosaic) with the same quant-aware cost hint as the fused path."""
    n, d = docs.shape
    b = queries.shape[0]
    assert n % tile_n == 0 and k <= tile_n
    tiles = n // tile_n
    ids_2d = doc_ids.reshape(tiles, tile_n)
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    scale_2d = scale.astype(jnp.float32).reshape(tiles, tile_n)
    if q_scale is None:
        q_scale = jnp.ones((b,), jnp.float32)
    qscale_col = q_scale.astype(jnp.float32).reshape(b, 1)
    kernel = functools.partial(_knn_kernel, k=k, tile_n=tile_n,
                               int8_dot=int8_dot)
    itemsize = jnp.dtype(docs.dtype).itemsize
    return pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),        # queries: resident
            pl.BlockSpec((b, 1), lambda i: (0, 0)),        # per-query scales
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # corpus tile stream
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile ids
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile doc scales
        ],
        out_specs=[
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, b, k), jnp.float32),
            jax.ShapeDtypeStruct((tiles, b, k), jnp.int32),
        ],
        cost_estimate=_scan_cost(n, d, b, k, itemsize, int8_dot),
        interpret=interpret,
    )(queries, qscale_col, docs, ids_2d, scale_2d)
