"""Fused corpus-scan + top-k Pallas TPU kernels — the paper's hot spot.

The exhaustive FAISS scan (paper Table 3, ~1 s / 216-query batch on a Xeon)
is re-thought for the TPU memory hierarchy:

  * grid over corpus tiles; each step DMAs one (TILE_N, D) tile HBM->VMEM,
  * scores = Q @ tile.T on the MXU (D is zero-padded to a lane multiple by
    the wrapper, which leaves inner products unchanged),
  * top-k extraction by iterative max-extract on the VPU, so the full (B, N)
    score matrix is NEVER materialized in HBM.

Two merge strategies:

  * ``knn_fused_topk`` — the serving kernel.  The running global top-k is a
    (B, k) carry held in VMEM *scratch* across grid steps: each tile's
    scores are merged against the carry in-register and only the final
    (B, k) answer is ever written to HBM.  The corpus is read exactly once
    and the candidate traffic of the two-stage scheme (O(tiles * B * k)
    rows through HBM plus a second launch to merge) disappears entirely.
    Validity is data-driven — scores at sentinel rows (id < 0) are masked
    to -inf — so one kernel serves unpadded, padded, and device-sharded
    corpora, and extracted -inf candidates report id -1, never a clipped
    real id.
  * ``knn_tile_topk`` — the original two-stage scheme (per-tile top-k
    candidates to HBM, cross-tile ``lax.top_k`` merge in the wrapper), kept
    as the A/B baseline for ``kernel_bench`` and for the k > tile_n regime.

Arithmetic intensity of the scan is ~2*B flops per corpus byte, so for
serving batches (B <= 256 at fp32) the kernel is HBM-bandwidth bound; the
design goal is to stream at full bandwidth, which the single-pass structure
achieves.  Quantized corpora (``repro.core.quant``: bf16 payloads, or int8
payloads with an fp32 per-document scale) stream 2x / 4x more documents per
HBM byte: tiles are dequantized *in VMEM* — payload cast to f32, scores
accumulated in f32, the per-document scale applied score-side — so the
only thing that shrinks is the HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _masked_scores(q, docs, ids, scale):
    """(B, TILE_N) scores with sentinel rows (id < 0) masked to -inf.

    ``docs`` may be fp32 / bf16 / int8: the payload is cast to f32 before
    the dot (dequantization happens here, in VMEM) and ``scale`` — the
    (1, TILE_N) per-document f32 score multiplier, all-ones for
    unquantized corpora — is applied to the scores, matching the shared
    ``quant.scale_scores`` rule of the ref tier bit for bit.
    """
    scores = jax.lax.dot_general(
        q.astype(jnp.float32), docs.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (B, TILE_N)
    scores = scores * scale
    return jnp.where(ids < 0, NEG_INF, scores)


def _fused_kernel(q_ref, docs_ref, ids_ref, scale_ref, out_vals_ref,
                  out_idx_ref, carry_v, carry_i, *, k: int):
    """One grid step: merge one corpus tile into the VMEM top-k carry."""
    tile = pl.program_id(0)

    @pl.when(tile == 0)
    def _init():
        carry_v[...] = jnp.full(carry_v.shape, NEG_INF, jnp.float32)
        carry_i[...] = jnp.full(carry_i.shape, -1, jnp.int32)

    q = q_ref[...]                                     # (B, D)
    docs = docs_ref[...]                               # (TILE_N, D) any dtype
    ids = ids_ref[...]                                 # (1, TILE_N) int32
    scale = scale_ref[...]                             # (1, TILE_N) f32
    scores = _masked_scores(q, docs, ids, scale)       # (B, TILE_N)

    # candidate pool = running carry ++ this tile; carry columns come first,
    # so equal scores resolve to the earliest corpus position — the same
    # tie-break a stable global lax.top_k applies.
    cand_v = jnp.concatenate([carry_v[...], scores], axis=1)
    cand_i = jnp.concatenate(
        [carry_i[...], jnp.broadcast_to(ids, scores.shape)], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)

    def extract(j, s):
        m = jnp.max(s, axis=1)                             # (B,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)        # (B,)
        hit = col == a[:, None]
        # one-hot reduce instead of a gather: id at the extracted column
        picked = jnp.sum(jnp.where(hit, cand_i, 0), axis=1).astype(jnp.int32)
        picked = jnp.where(m == NEG_INF, -1, picked)       # sentinel, not id
        carry_v[:, pl.dslice(j, 1)] = m[:, None]
        carry_i[:, pl.dslice(j, 1)] = picked[:, None]
        return jnp.where(hit, NEG_INF, s)

    jax.lax.fori_loop(0, k, extract, cand_v)

    @pl.when(tile == pl.num_programs(0) - 1)
    def _emit():
        out_vals_ref[...] = carry_v[...]
        out_idx_ref[...] = carry_i[...]


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def knn_fused_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                   k: int, tile_n: int = 1024, interpret: bool = False,
                   scale: jax.Array | None = None):
    """Single-launch exact top-k with the cross-tile merge on chip.

    docs: (N, D) payload (fp32 / bf16 / int8) padded to a tile_n multiple
    and lane-aligned D; doc_ids: (N,) int32 with -1 on padded/sentinel
    rows; queries: (B, D); scale: (N,) f32 per-document score multipliers
    (None for an unquantized corpus).  Returns (scores (B, k) f32
    descending, ids (B, k) int32, -1 at -inf positions).
    """
    n, d = docs.shape
    b = queries.shape[0]
    assert n % tile_n == 0
    tiles = n // tile_n
    ids_2d = doc_ids.reshape(tiles, tile_n)
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    scale_2d = scale.astype(jnp.float32).reshape(tiles, tile_n)
    kernel = functools.partial(_fused_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),        # queries: resident
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # corpus tile stream
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile ids
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile doc scales
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),               # running top-k vals
            pltpu.VMEM((b, k), jnp.int32),                 # running top-k ids
        ],
        interpret=interpret,
    )(queries, docs, ids_2d, scale_2d)


def _knn_kernel(q_ref, docs_ref, ids_ref, scale_ref, out_vals_ref,
                out_idx_ref, *, k: int, tile_n: int):
    """One grid step: score one corpus tile against all queries; emit top-k."""
    tile = pl.program_id(0)
    q = q_ref[...]                      # (B, D)
    docs = docs_ref[...]                # (TILE_N, D) any dtype
    ids = ids_ref[...]                  # (1, TILE_N) int32
    scale = scale_ref[...]              # (1, TILE_N) f32
    # same data-driven validity as the fused kernel: sentinel rows (id < 0)
    # can never win a per-tile extraction, wherever they sit in the corpus
    scores = _masked_scores(q, docs, ids, scale)      # (B, TILE_N)
    base = tile * tile_n

    def body(j, s):
        m = jnp.max(s, axis=1)                         # (B,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)    # (B,)
        out_vals_ref[0, :, pl.dslice(j, 1)] = m[:, None]
        out_idx_ref[0, :, pl.dslice(j, 1)] = (base + a)[:, None]
        # knock out the extracted column per row
        hit = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == a[:, None]
        return jnp.where(hit, NEG_INF, s)

    jax.lax.fori_loop(0, k, body, scores)


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def knn_tile_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                  k: int, tile_n: int = 1024, interpret: bool = False,
                  scale: jax.Array | None = None):
    """Per-tile top-k candidates (two-stage scheme). docs: (N, D) payload
    (fp32 / bf16 / int8) padded to a tile_n multiple and lane-aligned D;
    doc_ids: (N,) int32 with -1 on sentinel/padded rows (masked to -inf,
    same contract as the fused kernel); queries: (B, D); scale: (N,) f32
    per-document score multipliers or None. Returns (tiles, B, k) vals +
    idx; idx are *positions* in the padded corpus (a fully-masked
    extraction can emit any position at a -inf value — the wrapper must
    sentinel those on merge)."""
    n, d = docs.shape
    b = queries.shape[0]
    assert n % tile_n == 0 and k <= tile_n
    tiles = n // tile_n
    ids_2d = doc_ids.reshape(tiles, tile_n)
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    scale_2d = scale.astype(jnp.float32).reshape(tiles, tile_n)
    kernel = functools.partial(_knn_kernel, k=k, tile_n=tile_n)
    return pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),        # queries: resident
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # corpus tile stream
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile ids
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),   # tile doc scales
        ],
        out_specs=[
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, b, k), jnp.float32),
            jax.ShapeDtypeStruct((tiles, b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, docs, ids_2d, scale_2d)
