"""Fused corpus-scan + top-k Pallas TPU kernel — the paper's hot spot.

The exhaustive FAISS scan (paper Table 3, ~1 s / 216-query batch on a Xeon)
is re-thought for the TPU memory hierarchy:

  * grid over corpus tiles; each step DMAs one (TILE_N, D) tile HBM->VMEM,
  * scores = Q @ tile.T on the MXU (D is zero-padded to a lane multiple by
    the wrapper, which leaves inner products unchanged),
  * a per-tile top-k (iterative max-extract on the VPU) so the full (B, N)
    score matrix is NEVER materialized in HBM — the corpus is read exactly
    once and only O(tiles * B * k) candidates are written back.

Arithmetic intensity of the scan is ~2*B flops per corpus byte, so for
serving batches (B <= 256 at fp32) the kernel is HBM-bandwidth bound; the
design goal is to stream at full bandwidth, which the single-pass structure
achieves.  Final cross-tile merge is a tiny ``lax.top_k`` in the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _knn_kernel(q_ref, docs_ref, out_vals_ref, out_idx_ref, *, k: int,
                tile_n: int, n_docs: int):
    """One grid step: score one corpus tile against all queries; emit top-k."""
    tile = pl.program_id(0)
    q = q_ref[...]                      # (B, D)
    docs = docs_ref[...]                # (TILE_N, D)
    scores = jax.lax.dot_general(
        q, docs, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (B, TILE_N)

    # mask out padded corpus rows in the last tile
    base = tile * tile_n
    local = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(base + local < n_docs, scores, NEG_INF)

    def body(j, s):
        m = jnp.max(s, axis=1)                         # (B,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)    # (B,)
        out_vals_ref[0, :, pl.dslice(j, 1)] = m[:, None]
        out_idx_ref[0, :, pl.dslice(j, 1)] = (base + a)[:, None]
        # knock out the extracted column per row
        hit = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == a[:, None]
        return jnp.where(hit, NEG_INF, s)

    jax.lax.fori_loop(0, k, body, scores)


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "n_valid", "interpret"))
def knn_tile_topk(docs: jax.Array, queries: jax.Array, k: int,
                  tile_n: int = 1024, n_valid: int | None = None,
                  interpret: bool = False):
    """Per-tile top-k candidates. docs: (N, D) padded to tile_n multiple and
    lane-aligned D; queries: (B, D). ``n_valid``: original (unpadded) corpus
    size — padded rows are masked to -inf. Returns (tiles, B, k) vals + idx."""
    n, d = docs.shape
    b = queries.shape[0]
    assert n % tile_n == 0 and k <= tile_n
    tiles = n // tile_n
    kernel = functools.partial(_knn_kernel, k=k, tile_n=tile_n,
                               n_docs=n if n_valid is None else n_valid)
    return pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),        # queries: resident
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # corpus tile stream
        ],
        out_specs=[
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, b, k), jnp.float32),
            jax.ShapeDtypeStruct((tiles, b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, docs)
