"""Backend dispatch for the Pallas kernel layer.

Every kernel op ships in three tiers sharing one contract:

  * ``ref``       — pure-jnp implementation; the production path on CPU
                    hosts (interpret-mode Pallas is orders of magnitude
                    slower than XLA:CPU) and the oracle in tests/benches.
  * ``interpret`` — the Pallas kernel under the Pallas interpreter; used
                    off-TPU to exercise the *kernel code path* (CI runs the
                    equivalence suite in this tier on CPU).
  * ``compiled``  — the Mosaic-compiled Pallas kernel; the serving hot path
                    on TPU.

``default_backend()`` is what serving components (``MetricIndex``,
``probe_batched``, ``BatchedEngine``) use when the caller does not pin a
tier: compiled on TPU, ref elsewhere.  ``kernel_backend()`` is what an
*explicit* kernel entry point (``knn_search``, ``cache_probe``) uses:
calling the kernel off-TPU means you want the kernel, so it degrades to
interpret, never silently to ref.

The ``REPRO_KERNEL_BACKEND`` environment variable pins the default for a
whole process (e.g. ``REPRO_KERNEL_BACKEND=interpret`` to smoke the kernel
path in a CPU CI job without touching call sites).  Its sibling policies
live in ``repro.core.quant``: ``REPRO_CORPUS_DTYPE`` picks the
corpus/cache storage format the scan contract streams, and
``REPRO_INT8_DOT`` switches int8 corpora to the native int8-MXU scoring
rule; CI runs the kernel gate across the full backend x dtype matrix plus
the int8-MXU cells.
"""

from __future__ import annotations

import os

import jax

__all__ = ["BACKENDS", "on_tpu", "default_backend", "kernel_backend",
           "resolve", "interpret_flag"]

BACKENDS = ("ref", "interpret", "compiled")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _env_backend() -> str | None:
    env = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
    if not env:
        return None
    if env not in BACKENDS:
        raise ValueError(
            f"REPRO_KERNEL_BACKEND={env!r}: expected one of {BACKENDS}")
    return env


def default_backend() -> str:
    """Tier for serving components that did not pin one."""
    env = _env_backend()
    if env is not None:
        return env
    return "compiled" if on_tpu() else "ref"


def kernel_backend() -> str:
    """Tier for explicit kernel entry points (never degrades to ref)."""
    env = _env_backend()
    if env is not None and env != "ref":
        return env
    return "compiled" if on_tpu() else "interpret"


def resolve(backend: str | None, *, kernel: bool = False) -> str:
    """Validate ``backend``; None picks the appropriate default tier."""
    if backend is None:
        return kernel_backend() if kernel else default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r}: expected one of {BACKENDS}")
    return backend


def interpret_flag(backend: str) -> bool:
    """The ``interpret=`` argument a ``pallas_call`` wrapper should pass for
    an already-resolved non-ref backend."""
    if backend == "ref":
        raise ValueError("ref tier never reaches a pallas_call")
    return backend == "interpret"
