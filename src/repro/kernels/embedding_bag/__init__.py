"""Fused embedding-bag gather/pool kernel (see ``.ops``)."""

from repro.kernels.embedding_bag.ops import embedding_bag  # noqa: F401
