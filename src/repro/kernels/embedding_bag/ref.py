"""Pure-jnp oracle for EmbeddingBag (matches torch.nn.EmbeddingBag semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      weights: jax.Array | None = None,
                      mode: str = "sum") -> jax.Array:
    """table (V,D); indices (B,L) int32 with <0 as padding; weights (B,L)."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = table[safe].astype(jnp.float32)                    # (B, L, D)
    if mode == "max":
        masked = jnp.where(valid[..., None], rows, -jnp.inf)
        out = masked.max(axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)         # empty bag -> 0
    w = jnp.ones(indices.shape, jnp.float32) if weights is None else weights.astype(jnp.float32)
    w = w * valid
    out = jnp.einsum("bl,bld->bd", w, rows)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out
