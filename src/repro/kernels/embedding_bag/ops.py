"""Public EmbeddingBag op: kernel dispatch + padding + pure-JAX fallback.

The fallback (gather + einsum/segment reduce) is what runs inside jitted
model code on non-TPU backends and inside the dry-run lowering; the Pallas
kernel is selected on TPU (or explicitly, in interpret mode, for tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref

LANE = 128


@functools.partial(jax.jit, static_argnames=("mode", "use_kernel", "interpret"))
def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, mode: str = "sum",
                  use_kernel: bool = False, interpret: bool | None = None) -> jax.Array:
    """EmbeddingBag(table, indices) -> (B, D). indices < 0 are padding."""
    if not use_kernel:
        return embedding_bag_ref(table, indices, weights, mode)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    valid = indices >= 0
    w = jnp.ones(indices.shape, jnp.float32) if weights is None else weights.astype(jnp.float32)
    w = w * valid
    d = table.shape[1]
    pad = (-d) % LANE
    table_p = jnp.pad(table, ((0, 0), (0, pad))) if pad else table
    out = embedding_bag_kernel(table_p, indices, w, mode=mode, interpret=interpret)
    out = out[:, :d]
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    if mode == "max":
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    return out
