"""EmbeddingBag Pallas TPU kernel: ragged gather + segment reduce.

JAX has no native ``nn.EmbeddingBag``; for the recsys architectures the
embedding lookup IS the hot path (huge tables, tiny compute).  On TPU the
crux is that the table lives in HBM and rows are selected data-dependently —
exactly what Pallas *scalar prefetch* is for: the (B, L) index array is
prefetched to SMEM and drives the BlockSpec ``index_map``, so each grid step
DMAs only the one (1, D) table row it needs into VMEM.

Grid: (B, L).  Step (b, l) accumulates ``w[b,l] * table[idx[b,l]]`` into
``out[b]``.  Padding indices (< 0) are clamped to row 0 by the index_map and
zero-masked via the weight.  Reduction modes: sum (mean/max handled by the
wrapper; max uses the same gather with a maximum-accumulate variant).

Production note: this is the *functionally faithful* tiling; a
bandwidth-optimal variant would prefetch R>1 rows per step and double-buffer
the row DMAs.  The roofline for embedding lookup is pure HBM latency/bw —
(B*L) * D * bytes of random reads — which this layout already expresses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, w_ref, row_ref, out_ref, *, n_items: int, mode: str):
    b, l = pl.program_id(0), pl.program_id(1)
    w = w_ref[b, l]
    row = row_ref[...].astype(jnp.float32)  # (1, D)

    if mode == "max":
        @pl.when(l == 0)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)
        contrib = jnp.where(w > 0, row, -jnp.inf)
        out_ref[...] = jnp.maximum(out_ref[...], contrib)
    else:
        @pl.when(l == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)
        out_ref[...] += w * row


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_kernel(table: jax.Array, indices: jax.Array, weights: jax.Array,
                         mode: str = "sum", interpret: bool = False) -> jax.Array:
    """table: (V, D) lane-aligned; indices: (B, L) int32 (< 0 = pad);
    weights: (B, L) f32 (already zeroed at pads). Returns (B, D) f32."""
    bsz, bag = indices.shape
    v, d = table.shape
    safe_idx = jnp.where(indices >= 0, indices, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # indices, weights ride in SMEM
        grid=(bsz, bag),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, l, idx, w: (idx[b, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, l, idx, w: (b, 0)),
    )
    kernel = functools.partial(_bag_kernel, n_items=bag, mode=mode)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )(safe_idx, weights, table)
