"""Public wrappers for the fused wave kernels: padding + launch assembly.

Three entry points, all ONE ``pallas_call`` each (the launch-count contract
of the kernel-tier serving wave: probe -> miss-search -> insert+query is
exactly three launches):

  * ``wave_insert_query``   — the serving path: batched insert scatter
                              fused with the post-insert top-k query.
  * ``wave_query_topk``     — query-only (a wave with no misses).
  * ``wave_insert_scatter`` — insert-only (the ``insert_batched`` kernel
                              tier when no query follows).

The wrappers take plain stacked arrays (``core.cache`` orchestrates state
assembly and precomputes write positions/ring slots with the scalar ops'
exact jnp logic); they handle lane/sublane padding — feature dim to the
lane multiple, cache capacity to a power-of-two tile, the k_c batch and
query-record axes to the sublane multiple — and remap dropped write
positions past the *padded* capacity so a dropped document can never land
in a padded column and leak into the query scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cache_wave.cache_wave import make_wave_kernel

LANE = 128
SUBLANE = 8


def _pad_axis(x, axis, mult, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def wave_tile(capacity: int) -> int:
    """Capacity tile: one power of two <= 512 (whole cache when smaller)."""
    pow2 = max(SUBLANE, 1 << max(capacity - 1, 1).bit_length())
    return min(512, pow2)


def _common_specs(tile_c, dp):
    """(ints SMEM, doc payload, doc ids, doc scale) input specs."""
    return [
        pl.BlockSpec((1, 8), lambda i, t: (i, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, tile_c, dp), lambda i, t: (i, t, 0)),
        pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
        pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
    ]


def _launch(*, s, capacity, dp, kc, qmax, k, tile_c, store_dtype,
            radius_dtype, with_insert, with_query, interpret, operands):
    tiles = capacity // tile_c
    in_specs = _common_specs(tile_c, dp)
    out_specs, out_shape, scratch = [], [], []
    if with_insert:
        in_specs += [
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),        # stamps
            pl.BlockSpec((1, 8), lambda i, t: (i, 0),
                         memory_space=pltpu.SMEM),                 # floats
            pl.BlockSpec((1, kc, dp), lambda i, t: (i, 0, 0)),     # new emb
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # emb scale
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # new ids
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # positions
            pl.BlockSpec((1, 8, dp), lambda i, t: (i, 0, 0)),      # psi store
            pl.BlockSpec((1, qmax, dp), lambda i, t: (i, 0, 0)),   # q_emb
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),          # q_radius
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),          # q_scale
        ]
        out_specs += [
            pl.BlockSpec((1, tile_c, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, qmax, dp), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((s, capacity, dp), store_dtype),
            jax.ShapeDtypeStruct((s, capacity), jnp.int32),
            jax.ShapeDtypeStruct((s, capacity), jnp.int32),
            jax.ShapeDtypeStruct((s, capacity), jnp.float32),
            jax.ShapeDtypeStruct((s, qmax, dp), store_dtype),
            jax.ShapeDtypeStruct((s, qmax), radius_dtype),
            jax.ShapeDtypeStruct((s, qmax), jnp.float32),
        ]
    if with_query:
        in_specs += [
            pl.BlockSpec((1, 8, dp), lambda i, t: (i, 0, 0)),      # psi f32
        ]
        out_specs += [
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((s, k), jnp.float32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
        ]
        scratch += [
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
            pltpu.VMEM((1, k), jnp.int32),
        ]
    kernel = make_wave_kernel(tile_c=tile_c, tiles=tiles, kc=kc, k=k,
                              with_insert=with_insert, with_query=with_query)
    # one pass over the (S, capacity, D) cache payload, read + (on insert)
    # written back, plus the k_c batch and the tiny per-session blocks
    itemsize = jnp.dtype(store_dtype).itemsize
    payload = s * capacity * (dp * itemsize * (2 if with_insert else 1) + 12)
    batch = s * kc * (dp * itemsize + 12) if with_insert else 0
    cost = pl.CostEstimate(
        flops=2 * s * capacity * dp * ((kc if with_insert else 0)
                                       + (1 if with_query else 0)),
        bytes_accessed=payload + batch, transcendentals=0)
    return pl.pallas_call(
        kernel,
        grid=(s, tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        cost_estimate=cost,
        interpret=interpret,
    )(*operands)


def _pad_state(doc_emb, doc_ids, doc_scale, tile_c):
    """Sentinel-pad the per-session cache arrays to the tile multiple."""
    demb = _pad_axis(_pad_axis(doc_emb, 2, LANE), 1, tile_c)
    dids = _pad_axis(doc_ids, 1, tile_c, value=-1)
    dscale = _pad_axis(doc_scale.astype(jnp.float32), 1, tile_c, value=1.0)
    return demb, dids, dscale


def _psi_block(psi, dp):
    """(S, D) -> (S, 8, Dp): sublane-friendly single-row block, row 0 live."""
    p = _pad_axis(psi, 1, LANE)
    return _pad_axis(p[:, None, :], 1, SUBLANE)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wave_query_topk(doc_emb, doc_ids, doc_scale, psi, k: int,
                    interpret: bool = False):
    """Batched top-k over cached docs, one launch.  doc_emb (S, C, D)
    payload (any storage dtype), doc_ids (S, C) with -1 empties, doc_scale
    (S, C) f32, psi (S, D) f32.  Returns (vals (S, k) f32 — -inf past the
    cached docs, ids (S, k) int32 — -1 there, slots (S, k) int32) with the
    ref tier's exact slot ordering (stable top-k, empties ascending)."""
    s, capacity, d = doc_emb.shape
    assert k <= capacity, f"k={k} > capacity={capacity} (ref tier errors too)"
    tile_c = wave_tile(capacity)
    demb, dids, dscale = _pad_state(doc_emb, doc_ids, doc_scale, tile_c)
    ints = jnp.zeros((s, 8), jnp.int32)
    operands = (ints, demb, dids, dscale,
                _psi_block(psi.astype(jnp.float32), d))
    return _launch(
        s=s, capacity=demb.shape[1], dp=demb.shape[2], kc=0, qmax=0, k=k,
        tile_c=tile_c, store_dtype=doc_emb.dtype, radius_dtype=jnp.float32,
        with_insert=False, with_query=True, interpret=interpret,
        operands=operands)


def _insert_operands(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius,
                     q_scale, emb_q, emb_scale, new_ids, pos, psi_q,
                     psi_scale, radius, rec, qslot, step_ins, tile_c):
    s, capacity, d = doc_emb.shape
    demb, dids, dscale = _pad_state(doc_emb, doc_ids, doc_scale, tile_c)
    cpad = demb.shape[1]
    dstamp = _pad_axis(doc_stamp, 1, tile_c)
    # remap drop positions (== capacity) past the PADDED capacity: a padded
    # column is a real column of the launch and a doc written there would
    # leak into the query scan as a live id
    pos = jnp.where(pos >= capacity, cpad, pos.astype(jnp.int32))
    emb_p = _pad_axis(_pad_axis(emb_q, 2, LANE), 1, SUBLANE)
    kc_p = emb_p.shape[1]
    escale = _pad_axis(emb_scale.astype(jnp.float32), 1, SUBLANE,
                       value=1.0)[:, None, :]
    nids = _pad_axis(new_ids.astype(jnp.int32), 1, SUBLANE,
                     value=-1)[:, None, :]
    pos_p = _pad_axis(pos, 1, SUBLANE, value=cpad)[:, None, :]
    qemb = _pad_axis(_pad_axis(q_emb, 2, LANE), 1, SUBLANE)
    qmax_p = qemb.shape[1]
    qrad = _pad_axis(q_radius, 1, SUBLANE, value=-jnp.inf)
    qsc = _pad_axis(q_scale.astype(jnp.float32), 1, SUBLANE, value=1.0)
    psis = _pad_axis(_pad_axis(psi_q, 1, LANE)[:, None, :], 1, SUBLANE)
    ints = jnp.stack([
        jnp.zeros((s,), jnp.int32),
        jnp.asarray(rec, jnp.int32),
        jnp.asarray(qslot, jnp.int32),
        jnp.asarray(step_ins, jnp.int32),
    ] + [jnp.zeros((s,), jnp.int32)] * 4, axis=1)
    floats = jnp.stack([
        jnp.asarray(radius, jnp.float32),
        jnp.asarray(psi_scale, jnp.float32),
    ] + [jnp.zeros((s,), jnp.float32)] * 6, axis=1)
    operands = (ints, demb, dids, dscale, dstamp, floats, emb_p, escale,
                nids, pos_p, psis, qemb, qrad, qsc)
    dims = dict(s=s, capacity=cpad, dp=demb.shape[2], kc=kc_p, qmax=qmax_p,
                tile_c=tile_c, store_dtype=doc_emb.dtype,
                radius_dtype=q_radius.dtype)
    return operands, dims, capacity, d


def _unpad_insert_outs(outs, capacity, d, qmax):
    demb, dids, dstamp, dscale, qemb, qrad, qsc = outs[:7]
    return (demb[:, :capacity, :d], dids[:, :capacity], dstamp[:, :capacity],
            dscale[:, :capacity], qemb[:, :qmax, :d], qrad[:, :qmax],
            qsc[:, :qmax])


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave_insert_scatter(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb,
                        q_radius, q_scale, emb_q, emb_scale, new_ids, pos,
                        psi_q, psi_scale, radius, rec, qslot, step_ins,
                        interpret: bool = False):
    """Batched insert scatter, one launch.  ``pos`` (S, kc) are precomputed
    write positions (== capacity for dropped/masked docs); ``psi_q`` /
    ``psi_scale`` / ``radius`` the per-session query record, written at ring
    slot ``qslot`` when ``rec``; ``step_ins`` stamps the written rows.
    Returns the 7 post-insert doc/q arrays (counters stay with the
    caller)."""
    tile_c = wave_tile(doc_emb.shape[1])
    operands, dims, capacity, d = _insert_operands(
        doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius, q_scale,
        emb_q, emb_scale, new_ids, pos, psi_q, psi_scale, radius, rec,
        qslot, step_ins, tile_c)
    outs = _launch(**dims, k=0, with_insert=True, with_query=False,
                   interpret=interpret, operands=operands)
    return _unpad_insert_outs(outs, capacity, d, q_emb.shape[1])


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wave_insert_query(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb,
                      q_radius, q_scale, emb_q, emb_scale, new_ids, pos,
                      psi_q, psi_scale, radius, rec, qslot, step_ins,
                      psi, k: int, interpret: bool = False):
    """The fused serving wave: insert scatter + post-insert top-k query in
    ONE launch — the query scan scores each freshly blended tile, so the
    whole wave costs a single pass over the cache payload.  Returns
    (doc/q arrays as ``wave_insert_scatter``, (vals, ids, slots))."""
    capacity = doc_emb.shape[1]
    assert k <= capacity, f"k={k} > capacity={capacity} (ref tier errors too)"
    tile_c = wave_tile(capacity)
    operands, dims, capacity, d = _insert_operands(
        doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius, q_scale,
        emb_q, emb_scale, new_ids, pos, psi_q, psi_scale, radius, rec,
        qslot, step_ins, tile_c)
    operands = operands + (_psi_block(psi.astype(jnp.float32), d),)
    outs = _launch(**dims, k=k, with_insert=True, with_query=True,
                   interpret=interpret, operands=operands)
    state_outs = _unpad_insert_outs(outs, capacity, d, q_emb.shape[1])
    return state_outs, tuple(outs[7:])
