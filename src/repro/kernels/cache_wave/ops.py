"""Public wrappers for the fused wave kernels: zero-copy launch assembly.

Three entry points, all ONE ``pallas_call`` each (the launch-count contract
of the kernel-tier serving wave: probe -> miss-search -> insert+query is
exactly three launches):

  * ``wave_insert_query``   — the serving path: batched insert scatter
                              fused with the post-insert top-k query.
  * ``wave_query_topk``     — query-only (a wave with no misses).
  * ``wave_insert_scatter`` — insert-only (the ``insert_batched`` kernel
                              tier when no query follows).

The wrappers take plain stacked arrays (``core.cache`` orchestrates state
assembly and precomputes write positions/ring slots with the scalar ops'
exact jnp logic).  Since the pre-padded layout (``repro.core.layout``),
the STATE arrays arrive already at the physical extents — capacity a
multiple of the wave tile, feature dim a multiple of the lane, the query
ring a multiple of the sublane, scales f32 — and pass straight into the
launch: no per-launch pad of the O(S * capacity * dim) payload, no slice
back out.  Only per-wave INPUTS (the k_c new documents, the per-session
psi rows) still get lane/sublane-padded, which is O(wave).  Dropped write
positions arrive pre-routed past the physical capacity
(``core.cache._insert_positions``), so a dropped document can never land
in a padded column and leak into the query scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layout import LANE, SUBLANE, wave_tile
from repro.kernels.cache_wave.cache_wave import make_wave_kernel

__all__ = ["LANE", "SUBLANE", "wave_tile", "wave_query_topk",
           "wave_insert_scatter", "wave_insert_query"]


def _pad_axis(x, axis, mult, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _check_state(doc_emb, doc_scale, tile_c):
    """The zero-copy contract: state arrays must arrive pre-padded (see
    ``core.cache.init_cache``) — the wave wrappers no longer pad them."""
    s, capacity, d = doc_emb.shape
    assert capacity % tile_c == 0, (
        f"capacity {capacity} not a multiple of the wave tile {tile_c}: "
        "pass a pre-padded CacheState (init_cache allocates phys_capacity)")
    assert d % LANE == 0, (
        f"feature dim {d} not a multiple of the lane {LANE}: pass a "
        "pre-padded CacheState (init_cache allocates phys_dim)")
    assert doc_scale.dtype == jnp.float32, (
        f"doc_scale must be stored f32, got {doc_scale.dtype}")


def _common_specs(tile_c, dp):
    """(ints SMEM, doc payload, doc ids, doc scale) input specs."""
    return [
        pl.BlockSpec((1, 8), lambda i, t: (i, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, tile_c, dp), lambda i, t: (i, t, 0)),
        pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
        pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
    ]


def _launch(*, s, capacity, dp, kc, qmax, k, tile_c, store_dtype,
            radius_dtype, with_insert, with_query, interpret, operands):
    tiles = capacity // tile_c
    in_specs = _common_specs(tile_c, dp)
    out_specs, out_shape, scratch = [], [], []
    if with_insert:
        in_specs += [
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),        # stamps
            pl.BlockSpec((1, 8), lambda i, t: (i, 0),
                         memory_space=pltpu.SMEM),                 # floats
            pl.BlockSpec((1, kc, dp), lambda i, t: (i, 0, 0)),     # new emb
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # emb scale
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # new ids
            pl.BlockSpec((1, 1, kc), lambda i, t: (i, 0, 0)),      # positions
            pl.BlockSpec((1, 8, dp), lambda i, t: (i, 0, 0)),      # psi store
            pl.BlockSpec((1, qmax, dp), lambda i, t: (i, 0, 0)),   # q_emb
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),          # q_radius
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),          # q_scale
        ]
        out_specs += [
            pl.BlockSpec((1, tile_c, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, tile_c), lambda i, t: (i, t)),
            pl.BlockSpec((1, qmax, dp), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),
            pl.BlockSpec((1, qmax), lambda i, t: (i, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((s, capacity, dp), store_dtype),
            jax.ShapeDtypeStruct((s, capacity), jnp.int32),
            jax.ShapeDtypeStruct((s, capacity), jnp.int32),
            jax.ShapeDtypeStruct((s, capacity), jnp.float32),
            jax.ShapeDtypeStruct((s, qmax, dp), store_dtype),
            jax.ShapeDtypeStruct((s, qmax), radius_dtype),
            jax.ShapeDtypeStruct((s, qmax), jnp.float32),
        ]
    if with_query:
        in_specs += [
            pl.BlockSpec((1, 8, dp), lambda i, t: (i, 0, 0)),      # psi f32
        ]
        out_specs += [
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((s, k), jnp.float32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
        ]
        scratch += [
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
            pltpu.VMEM((1, k), jnp.int32),
        ]
    kernel = make_wave_kernel(tile_c=tile_c, tiles=tiles, kc=kc, k=k,
                              with_insert=with_insert, with_query=with_query)
    # one pass over the (S, capacity, D) cache payload, read + (on insert)
    # written back, plus the k_c batch and the tiny per-session blocks
    itemsize = jnp.dtype(store_dtype).itemsize
    payload = s * capacity * (dp * itemsize * (2 if with_insert else 1) + 12)
    batch = s * kc * (dp * itemsize + 12) if with_insert else 0
    cost = pl.CostEstimate(
        flops=2 * s * capacity * dp * ((kc if with_insert else 0)
                                       + (1 if with_query else 0)),
        bytes_accessed=payload + batch, transcendentals=0)
    return pl.pallas_call(
        kernel,
        grid=(s, tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        cost_estimate=cost,
        interpret=interpret,
    )(*operands)


def _psi_block(psi, dp):
    """(S, D) -> (S, 8, Dp): sublane-friendly single-row block, row 0 live.
    A per-wave O(S * dim) pad — one pad, never O(capacity)."""
    return jnp.pad(psi[:, None, :],
                   ((0, 0), (0, SUBLANE - 1), (0, dp - psi.shape[1])))


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wave_query_topk(doc_emb, doc_ids, doc_scale, psi, k: int,
                    interpret: bool = False):
    """Batched top-k over cached docs, one launch.  doc_emb (S, Cp, Dp)
    pre-padded payload (any storage dtype), doc_ids (S, Cp) with -1 empties
    (padded columns included), doc_scale (S, Cp) f32, psi (S, dim) f32 —
    the one per-wave input, lane-padded here.  Returns (vals (S, k) f32 —
    -inf past the cached docs, ids (S, k) int32 — -1 there, slots (S, k)
    int32) with the ref tier's exact slot ordering (stable top-k, empties
    ascending — so padded columns, which sit past every logical slot, are
    unreachable while k <= the logical capacity)."""
    s, capacity, d = doc_emb.shape
    assert k <= capacity, f"k={k} > capacity={capacity} (ref tier errors too)"
    tile_c = wave_tile(capacity)
    _check_state(doc_emb, doc_scale, tile_c)
    ints = jnp.zeros((s, 8), jnp.int32)
    operands = (ints, doc_emb, doc_ids, doc_scale,
                _psi_block(psi.astype(jnp.float32), d))
    return _launch(
        s=s, capacity=capacity, dp=d, kc=0, qmax=0, k=k,
        tile_c=tile_c, store_dtype=doc_emb.dtype, radius_dtype=jnp.float32,
        with_insert=False, with_query=True, interpret=interpret,
        operands=operands)


def _insert_operands(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius,
                     q_scale, emb_q, emb_scale, new_ids, pos, psi_q,
                     psi_scale, radius, rec, qslot, step_ins, tile_c):
    """Assemble the insert launch operands.  State arrays pass through
    untouched (pre-padded layout); only the per-wave inputs — the k_c new
    rows, their metadata, and the psi record row — get lane/sublane pads.
    ``pos`` arrives with drops already routed to the PHYSICAL capacity, so
    the pad value for the position block is simply ``capacity``."""
    s, capacity, d = doc_emb.shape
    _check_state(doc_emb, doc_scale, tile_c)
    assert q_emb.shape[1] % SUBLANE == 0 and q_emb.shape[2] == d, (
        f"query ring {q_emb.shape} not pre-padded to (*, {SUBLANE}-multiple,"
        f" {d}): pass a pre-padded CacheState")
    assert q_scale.dtype == jnp.float32, "q_scale must be stored f32"
    assert emb_q.shape[2] <= d and psi_q.shape[1] <= d
    # one fused pad per wave input (rows to the sublane, features to the
    # state's physical width) — two chained pads would materialize twice
    emb_p = jnp.pad(emb_q, ((0, 0), (0, (-emb_q.shape[1]) % SUBLANE),
                            (0, d - emb_q.shape[2])))
    kc_p = emb_p.shape[1]
    escale = _pad_axis(emb_scale.astype(jnp.float32), 1, SUBLANE,
                       value=1.0)[:, None, :]
    nids = _pad_axis(new_ids.astype(jnp.int32), 1, SUBLANE,
                     value=-1)[:, None, :]
    pos_p = _pad_axis(pos.astype(jnp.int32), 1, SUBLANE,
                      value=capacity)[:, None, :]
    psis = jnp.pad(psi_q[:, None, :],
                   ((0, 0), (0, SUBLANE - 1), (0, d - psi_q.shape[1])))
    ints = jnp.stack([
        jnp.zeros((s,), jnp.int32),
        jnp.asarray(rec, jnp.int32),
        jnp.asarray(qslot, jnp.int32),
        jnp.asarray(step_ins, jnp.int32),
    ] + [jnp.zeros((s,), jnp.int32)] * 4, axis=1)
    floats = jnp.stack([
        jnp.asarray(radius, jnp.float32),
        jnp.asarray(psi_scale, jnp.float32),
    ] + [jnp.zeros((s,), jnp.float32)] * 6, axis=1)
    operands = (ints, doc_emb, doc_ids, doc_scale, doc_stamp, floats, emb_p,
                escale, nids, pos_p, psis, q_emb, q_radius, q_scale)
    dims = dict(s=s, capacity=capacity, dp=d, kc=kc_p, qmax=q_emb.shape[1],
                tile_c=tile_c, store_dtype=doc_emb.dtype,
                radius_dtype=q_radius.dtype)
    return operands, dims


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave_insert_scatter(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb,
                        q_radius, q_scale, emb_q, emb_scale, new_ids, pos,
                        psi_q, psi_scale, radius, rec, qslot, step_ins,
                        interpret: bool = False):
    """Batched insert scatter, one launch over the pre-padded state.
    ``pos`` (S, kc) are precomputed write positions (== the physical
    capacity for dropped/masked docs); ``psi_q`` / ``psi_scale`` /
    ``radius`` the per-session query record, written at ring slot
    ``qslot`` when ``rec``; ``step_ins`` stamps the written rows.
    Returns the 7 post-insert doc/q arrays at the physical extents,
    unsliced (counters stay with the caller)."""
    tile_c = wave_tile(doc_emb.shape[1])
    operands, dims = _insert_operands(
        doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius, q_scale,
        emb_q, emb_scale, new_ids, pos, psi_q, psi_scale, radius, rec,
        qslot, step_ins, tile_c)
    return _launch(**dims, k=0, with_insert=True, with_query=False,
                   interpret=interpret, operands=operands)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wave_insert_query(doc_emb, doc_ids, doc_stamp, doc_scale, q_emb,
                      q_radius, q_scale, emb_q, emb_scale, new_ids, pos,
                      psi_q, psi_scale, radius, rec, qslot, step_ins,
                      psi, k: int, interpret: bool = False):
    """The fused serving wave: insert scatter + post-insert top-k query in
    ONE launch — the query scan scores each freshly blended tile, so the
    whole wave costs a single pass over the cache payload.  Returns
    (doc/q arrays as ``wave_insert_scatter``, (vals, ids, slots))."""
    s, capacity, d = doc_emb.shape
    assert k <= capacity, f"k={k} > capacity={capacity} (ref tier errors too)"
    tile_c = wave_tile(capacity)
    operands, dims = _insert_operands(
        doc_emb, doc_ids, doc_stamp, doc_scale, q_emb, q_radius, q_scale,
        emb_q, emb_scale, new_ids, pos, psi_q, psi_scale, radius, rec,
        qslot, step_ins, tile_c)
    operands = operands + (_psi_block(psi.astype(jnp.float32), d),)
    outs = _launch(**dims, k=k, with_insert=True, with_query=True,
                   interpret=interpret, operands=operands)
    return tuple(outs[:7]), tuple(outs[7:])
