"""Fused per-wave cache-op Pallas kernels: batched insert scatter + top-k.

The last two per-wave ops of a ``BatchedEngine`` turn — the k_c-document
insert into each missed session's cache and the top-k query over every
session's cached docs — used to be vmaps of the scalar jnp ops.  Here they
are ONE Pallas launch over the stacked ``CacheState``:

  grid = (sessions, capacity tiles); for each session the kernel streams
  the cache payload through VMEM once, and per tile

    1. **insert blend** (when inserting): a one-hot scatter computed on the
       MXU — ``hit[j, c] = (pos[j] == c)`` over the tile's column range,
       new rows land via ``one_hotᵀ @ new_emb`` and everything else passes
       through — writing the post-insert payload/ids/stamps/scales tile.
       Write positions are *precomputed* by ``core.cache`` with the exact
       jnp position logic of the scalar ``insert`` (dedup, append,
       LRU/ball eviction), so the kernel is a pure scatter and supports
       every eviction policy; a session whose ``do`` mask is False gets
       all-dropped positions and passes through bit-identically (its LRU
       stamps are untouched by construction).  The (psi, r_a) query-record
       ring update — payload row, radius, scale at the ring slot — happens
       on the first tile, gated by the per-session ``record`` flag.
    2. **query scan** (when querying): the freshly blended tile is scored
       against the session's psi (f32 dot + score-side scale, the shared
       quant rule) and merged into a (1, k) VMEM carry — the same
       on-chip cross-tile merge as the fused kNN scan, so the whole
       per-session top-k costs one pass over the cache payload that the
       insert already paid for.

Empty/sentinel slots must surface in the *same order* the ref tier's
stable ``lax.top_k`` yields (ascending slot index after all finite
scores), so the merge uses finite sentinels instead of -inf: empty slots
carry ``BIG_NEG``, the carry initializes to ``INIT`` (< BIG_NEG, so real
empty slots outrank unfilled carry entries), and extracted candidates are
knocked to ``KNOCK`` (< INIT).  argmax's first-match tie-break then walks
empty slots in ascending order across tiles — exactly the ref order — and
the wrapper maps keys <= BIG_NEG back to (-inf, id -1) on emit.

Real scores are inner products of unit-norm embeddings times ~1.0 scales;
anything below -1e37 is physically impossible, so the sentinel bands are
unreachable by data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
BIG_NEG = -1.0e38    # empty/sentinel slot key (extracted after all finite)
INIT = -2.0e38       # carry initialization (never outranks an empty slot)
KNOCK = -3.0e38      # already-extracted candidate


def make_wave_kernel(*, tile_c: int, tiles: int, kc: int, k: int,
                     with_insert: bool, with_query: bool):
    """Build the fused wave kernel body for a static mode/shape set.

    The ref operand list depends on the static flags; see
    ``repro.kernels.cache_wave.ops`` for the exact ordering (inputs,
    then outputs, then scratch).
    """

    def kernel(*refs):
        it = iter(refs)
        ints_ref = next(it)                       # SMEM (1, 8) int32
        demb_ref = next(it)                       # (1, TILE_C, D) payload
        dids_ref = next(it)                       # (1, TILE_C) int32
        dscale_ref = next(it)                     # (1, TILE_C) f32
        if with_insert:
            dstamp_ref = next(it)                 # (1, TILE_C) int32
            floats_ref = next(it)                 # SMEM (1, 8) f32
            emb_ref = next(it)                    # (1, KC, D) payload
            escale_ref = next(it)                 # (1, 1, KC) f32
            nids_ref = next(it)                   # (1, 1, KC) int32
            pos_ref = next(it)                    # (1, 1, KC) int32
            psis_ref = next(it)                   # (1, 8, D) payload, row 0
            qemb_ref = next(it)                   # (1, QMAX, D) payload
            qrad_ref = next(it)                   # (1, QMAX) radius dtype
            qsc_ref = next(it)                    # (1, QMAX) f32
        if with_query:
            psi_ref = next(it)                    # (1, 8, D) f32, row 0 live
        if with_insert:
            o_demb = next(it)
            o_dids = next(it)
            o_dstamp = next(it)
            o_dscale = next(it)
            o_qemb = next(it)
            o_qrad = next(it)
            o_qsc = next(it)
        if with_query:
            o_vals = next(it)                     # (1, k) f32
            o_ids = next(it)                      # (1, k) int32
            o_slots = next(it)                    # (1, k) int32
            carry_v = next(it)                    # VMEM (1, k) f32
            carry_i = next(it)                    # VMEM (1, k) int32
            carry_s = next(it)                    # VMEM (1, k) int32

        t = pl.program_id(1)
        old_emb = demb_ref[0]                     # (TILE_C, D) payload
        old_ids = dids_ref[...]                   # (1, TILE_C)
        old_scale = dscale_ref[...]               # (1, TILE_C)

        if with_insert:
            rec = ints_ref[0, 1]
            qslot = ints_ref[0, 2]
            step_ins = ints_ref[0, 3]
            base = t * tile_c
            pos_c = pos_ref[0].reshape(kc, 1)     # (KC, 1)
            col = base + jax.lax.broadcasted_iota(jnp.int32, (kc, tile_c), 1)
            hit = pos_c == col                    # (KC, TILE_C) one-hot-ish
            written = hit.any(axis=0, keepdims=True)          # (1, TILE_C)
            # MXU scatter: exactly one hit per written column (positions are
            # unique among kept docs), so the f32 matmul reproduces the row
            # values exactly — including int8/bf16 payloads, whose values
            # round-trip f32 without loss.
            scat = jax.lax.dot_general(
                hit.astype(jnp.float32), emb_ref[0].astype(jnp.float32),
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)           # (TILE_C, D)
            blended = jnp.where(written.reshape(tile_c, 1), scat,
                                old_emb.astype(jnp.float32))
            ids_c = nids_ref[0].reshape(kc, 1)
            scat_ids = jnp.sum(
                jnp.where(hit, jnp.broadcast_to(ids_c, hit.shape), 0),
                axis=0, keepdims=True).astype(jnp.int32)
            ids_bl = jnp.where(written, scat_ids, old_ids)
            sc_c = escale_ref[0].reshape(kc, 1)
            scat_sc = jnp.sum(
                jnp.where(hit, jnp.broadcast_to(sc_c, hit.shape), 0.0),
                axis=0, keepdims=True)
            scale_bl = jnp.where(written, scat_sc, old_scale)
            o_demb[0] = blended.astype(o_demb.dtype)
            o_dids[...] = ids_bl
            o_dscale[...] = scale_bl
            o_dstamp[...] = jnp.where(written, step_ins, dstamp_ref[...])

            @pl.when(t == 0)
            def _ring():                          # (psi, r_a) record ring
                o_qemb[0] = qemb_ref[0]
                o_qrad[...] = qrad_ref[...]
                o_qsc[...] = qsc_ref[...]

                @pl.when(rec == 1)
                def _write_slot():
                    o_qemb[0, pl.ds(qslot, 1), :] = psis_ref[0, :1, :]
                    o_qrad[0, pl.ds(qslot, 1)] = jnp.full(
                        (1,), floats_ref[0, 0], o_qrad.dtype)
                    o_qsc[0, pl.ds(qslot, 1)] = jnp.full(
                        (1,), floats_ref[0, 1], jnp.float32)
        else:
            blended = old_emb.astype(jnp.float32)
            ids_bl = old_ids
            scale_bl = old_scale

        if with_query:
            @pl.when(t == 0)
            def _init():
                carry_v[...] = jnp.full(carry_v.shape, INIT, jnp.float32)
                carry_i[...] = jnp.full(carry_i.shape, -1, jnp.int32)
                carry_s[...] = jnp.full(carry_s.shape, -1, jnp.int32)

            psi_row = psi_ref[0, :1, :]                        # (1, D)
            scores = jax.lax.dot_general(
                psi_row, blended, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)            # (1, TILE_C)
            scores = scores * scale_bl
            key = jnp.where(ids_bl < 0, BIG_NEG, scores)
            slot_row = (t * tile_c
                        + jax.lax.broadcasted_iota(jnp.int32, key.shape, 1))

            cand_v = jnp.concatenate([carry_v[...], key], axis=1)
            cand_i = jnp.concatenate([carry_i[...], ids_bl], axis=1)
            cand_s = jnp.concatenate([carry_s[...], slot_row], axis=1)
            col2 = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)

            def extract(j, s):
                m = jnp.max(s, axis=1)
                a = jnp.argmax(s, axis=1).astype(jnp.int32)
                hitc = col2 == a[:, None]
                pid = jnp.sum(jnp.where(hitc, cand_i, 0),
                              axis=1).astype(jnp.int32)
                pslot = jnp.sum(jnp.where(hitc, cand_s, 0),
                                axis=1).astype(jnp.int32)
                carry_v[:, pl.dslice(j, 1)] = m[:, None]
                carry_i[:, pl.dslice(j, 1)] = pid[:, None]
                carry_s[:, pl.dslice(j, 1)] = pslot[:, None]
                return jnp.where(hitc, KNOCK, s)

            jax.lax.fori_loop(0, k, extract, cand_v)

            @pl.when(t == tiles - 1)
            def _emit():
                v = carry_v[...]
                live = v > BIG_NEG
                o_vals[...] = jnp.where(live, v, NEG_INF)
                o_ids[...] = jnp.where(live, carry_i[...], -1)
                o_slots[...] = carry_s[...]

    return kernel
