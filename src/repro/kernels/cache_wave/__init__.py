"""Fused per-wave cache-op kernels (batched insert scatter + top-k query).

``ops`` holds the public single-launch entry points; ``cache_wave`` the raw
Pallas kernel builder.  ``core.cache`` dispatches ``query_batched`` /
``insert_batched`` / ``insert_query_batched`` here off the ref tier.
"""

from repro.kernels.cache_wave.ops import (wave_insert_query,  # noqa: F401
                                          wave_insert_scatter,
                                          wave_query_topk)
