"""Jaxpr inspection helpers for the zero-copy launch contract.

The pre-padded cache layout (``repro.core.layout``) promises that a
kernel-tier serving wave moves only wave-sized operands outside its Pallas
launches — no pad / slice / copy of the O(S * capacity * dim) stacked
``CacheState`` payload.  These helpers make that promise checkable: walk a
traced jaxpr's OUTER equations (recursing through ``pjit``/control-flow
call equations, but never into a ``pallas_call``'s inner kernel jaxpr,
whose payload traffic is the launch's job), and

  * ``payload_copy_eqns`` flags data-movement primitives whose output
    reaches a size threshold (the tier-1 guard in
    ``tests/test_padded_layout.py`` sets it to the stacked payload size),
  * ``moved_bytes`` totals the bytes produced by all non-launch outer
    equations (the ``wave_moved_bytes`` column of ``serve_bench``) — a
    machine-independent measure of per-wave overhead traffic,
  * ``pallas_call_count`` counts launches (the 3-launch wave contract).
"""

from __future__ import annotations

from typing import Iterator

import jax

# Primitives that MATERIALIZE a copy / re-layout of their operand — XLA
# cannot fuse these away, so their outputs are real memory traffic.
# ``broadcast`` variants and elementwise ops (``select_n``, arithmetic)
# are excluded: they fuse into consumers and move nothing by themselves.
MOVED_PRIMS = frozenset({
    "pad", "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
    "copy", "gather", "scatter", "scatter-add",
})

# For the payload-copy GUARD, a payload-sized ``select_n`` also counts: a
# full-state ``jnp.where`` (e.g. a vmap-ref session merge) reads and writes
# the whole payload even if XLA fuses the select itself.
COPY_PRIMS = MOVED_PRIMS | {"select_n"}


def _sub_jaxprs(eqn) -> list:
    """Inner jaxprs of a call / control-flow equation (empty for leaves)."""
    found = []

    def _walk(v):
        if hasattr(v, "eqns"):          # raw Jaxpr
            found.append(v)
        elif hasattr(v, "jaxpr"):       # ClosedJaxpr
            found.append(v.jaxpr)
        elif isinstance(v, (tuple, list)):
            for x in v:
                _walk(x)

    for v in eqn.params.values():
        _walk(v)
    return found


def outer_eqns(jaxpr) -> Iterator:
    """All equations reachable OUTSIDE pallas kernel bodies.

    Call equations (pjit, cond branches, scan bodies, ...) are expanded —
    their inner equations are yielded, the call shell itself is not, so
    nothing is double-counted.  ``pallas_call`` equations are yielded as
    leaves: their inner kernel jaxpr is the launch, not overhead.
    """
    if hasattr(jaxpr, "jaxpr"):         # accept ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
            continue
        sub = _sub_jaxprs(eqn)
        if sub:
            for j in sub:
                yield from outer_eqns(j)
        else:
            yield eqn


def pallas_call_count(jaxpr) -> int:
    return sum(1 for e in outer_eqns(jaxpr)
               if e.primitive.name == "pallas_call")


def payload_copy_eqns(jaxpr, min_size: int) -> list:
    """Copy-primitive equations whose output holds >= ``min_size`` elements
    — empty for a zero-copy wave traced at the stacked payload size."""
    flagged = []
    for eqn in outer_eqns(jaxpr):
        if eqn.primitive.name in COPY_PRIMS:
            if any(getattr(v.aval, "size", 0) >= min_size
                   for v in eqn.outvars):
                flagged.append(eqn)
    return flagged


def moved_bytes(jaxpr) -> int:
    """Total bytes produced by materializing (``MOVED_PRIMS``) outer
    equations — the wave's overhead data movement.  The launches' own
    payload traffic is intentional and excluded, and fusable elementwise
    ops are not charged (XLA never materializes them)."""
    total = 0
    for eqn in outer_eqns(jaxpr):
        if eqn.primitive.name not in MOVED_PRIMS:
            continue
        for v in eqn.outvars:
            aval = v.aval
            if hasattr(aval, "size") and hasattr(aval, "dtype"):
                total += int(aval.size) * aval.dtype.itemsize
    return total


def trace_moved_bytes(fn, *args, **kwargs) -> int:
    """``moved_bytes`` of ``jax.make_jaxpr(fn)(*args, **kwargs)``."""
    return moved_bytes(jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args))
