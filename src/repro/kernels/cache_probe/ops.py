"""Public wrappers: mask handling + hit decision for the probe kernels.

``cache_probe`` is the single-session entry point; ``cache_probe_batched``
fuses a whole serving wave — S sessions' LowQuality tests — into one
Pallas launch over the stacked cache state.  Both apply the ring-buffer
validity mask (a slot is live iff its index < min(n_queries, the LOGICAL
``max_queries``); n_queries counts *total* records, so a wrapped ring
keeps every logical slot live) by folding -inf into the radius operand,
both accept quantized record storage (the ``q_scale`` per-record score
multipliers of ``repro.core.quant``; padded slots get scale 1), and both
return nearest_q = -1 for a cache that holds no query records.

Pre-padded layout: states from ``init_cache`` arrive with the ring
already at the sublane multiple and the feature dim at the lane multiple,
so the shape-static padding branches below trace to NOTHING for them —
zero-copy launches.  The branches stay for direct callers with arbitrary
shapes (the public contract); they are O(ring), not O(doc capacity),
either way.  Only the per-wave psi rows are always assembled fresh,
which is O(wave).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layout import LANE, SUBLANE
from repro.kernels import dispatch
from repro.kernels.cache_probe.cache_probe import probe_rhat, probe_rhat_batched

__all__ = ["LANE", "SUBLANE", "cache_probe", "cache_probe_batched"]


@functools.partial(jax.jit, static_argnames=("interpret", "max_queries"))
def cache_probe(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
                n_queries: jax.Array, epsilon,
                q_scale: jax.Array | None = None,
                interpret: bool | None = None,
                max_queries: int | None = None):
    """Fused LowQuality test. q_emb (Qmax, D) record payload (any storage
    dtype); psi (D,) f32; radius (Qmax,); n_queries scalar; q_scale (Qmax,)
    f32 per-record score multipliers (None = unquantized); ``max_queries``
    the LOGICAL ring length when the state is pre-padded (None = every
    slot logical).  Returns (hit, best_r_hat, best_idx)."""
    if interpret is None:
        interpret = dispatch.interpret_flag(dispatch.resolve(None, kernel=True))
    qmax, d = q_emb.shape
    dpad = (-d) % LANE
    qpad = (-qmax) % SUBLANE
    if dpad or qpad:  # not taken for pre-padded states: zero traced pads
        q_emb = jnp.pad(q_emb, ((0, qpad), (0, dpad)))
        radius = jnp.pad(radius, (0, qpad), constant_values=-jnp.inf)
        if q_scale is not None:
            q_scale = jnp.pad(q_scale.astype(jnp.float32), (0, qpad),
                              constant_values=1.0)
    # psi arrives at the LOGICAL dim; pad it to the state's physical width
    # (O(wave), and a no-op for callers passing pre-padded rows)
    psi_p = jnp.pad(psi[None],
                    ((0, SUBLANE - 1), (0, d + dpad - psi.shape[0])))
    if q_scale is None:
        q_scale = jnp.ones((qmax + qpad,), jnp.float32)
    mq = qmax if max_queries is None else max_queries
    idx = jnp.arange(qmax + qpad)
    valid = jnp.logical_and(idx < n_queries, idx < mq)
    radius_m = jnp.where(valid, radius, -jnp.inf)
    r_hat = probe_rhat(q_emb, psi_p, radius_m[:, None],
                       q_scale.astype(jnp.float32)[:, None],
                       interpret=interpret)[:, 0]
    r_hat = jnp.where(valid, r_hat, -jnp.inf)
    best = jnp.argmax(r_hat)
    hit = jnp.logical_and(n_queries > 0, r_hat[best] >= epsilon)
    return hit, r_hat[best], jnp.where(n_queries > 0, best, -1)


@functools.partial(jax.jit, static_argnames=("interpret", "max_queries"))
def cache_probe_batched(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
                        n_queries: jax.Array, epsilon,
                        q_scale: jax.Array | None = None,
                        interpret: bool | None = None,
                        max_queries: int | None = None):
    """One fused LowQuality test per session, one kernel launch total.

    q_emb (S, Qmax, D) stacked record payload (any storage dtype); psi
    (S, D) f32 — the wave's queries; radius (S, Qmax); n_queries (S,)
    total-record counters (ring semantics: valid slots are those with
    index < min(n_queries, max_queries)); q_scale (S, Qmax) f32 per-record
    score multipliers (None = unquantized); ``max_queries`` the LOGICAL
    ring length from ``CacheConfig`` for pre-padded states (None = every
    slot logical; padded slots' -inf radius sentinels keep them out of
    the argmax regardless).  Returns (hit (S,) bool, best_r_hat (S,) f32,
    best_idx (S,) int32 with -1 for empty caches).
    """
    if interpret is None:
        interpret = dispatch.interpret_flag(dispatch.resolve(None, kernel=True))
    s, qmax, d = q_emb.shape
    dpad = (-d) % LANE
    qpad = (-qmax) % SUBLANE
    if dpad or qpad:  # not taken for pre-padded states: zero traced pads
        q_emb = jnp.pad(q_emb, ((0, 0), (0, qpad), (0, dpad)))
        radius = jnp.pad(radius, ((0, 0), (0, qpad)),
                         constant_values=-jnp.inf)
        if q_scale is not None:
            q_scale = jnp.pad(q_scale.astype(jnp.float32),
                              ((0, 0), (0, qpad)), constant_values=1.0)
    # psi arrives at the LOGICAL dim; pad it to the state's physical width
    # (O(wave), and a no-op for callers passing pre-padded rows)
    psi_p = jnp.broadcast_to(
        jnp.pad(psi, ((0, 0), (0, d + dpad - psi.shape[1])))[:, None, :],
        (s, SUBLANE, d + dpad))
    if q_scale is None:
        q_scale = jnp.ones((s, qmax + qpad), jnp.float32)
    # ring-aware validity: n_queries is the monotone total, so a wrapped
    # ring (n_queries >= max_queries) keeps every LOGICAL slot live;
    # allocation-padding slots past max_queries stay dead forever
    mq = qmax if max_queries is None else max_queries
    idx = jnp.arange(qmax + qpad)[None, :]
    valid = jnp.logical_and(idx < n_queries[:, None], idx < mq)  # (S, Qp)
    radius_m = jnp.where(valid, radius, -jnp.inf)
    r_hat = probe_rhat_batched(q_emb, psi_p, radius_m[..., None],
                               q_scale.astype(jnp.float32)[..., None],
                               interpret=interpret)[..., 0]      # (S, Qp)
    r_hat = jnp.where(valid, r_hat, -jnp.inf)
    best = jnp.argmax(r_hat, axis=1)
    best_r = jnp.take_along_axis(r_hat, best[:, None], axis=1)[:, 0]
    has_q = n_queries > 0
    hit = jnp.logical_and(has_q, best_r >= epsilon)
    return hit, best_r, jnp.where(has_q, best.astype(jnp.int32), -1)
