"""Public wrapper: pad/mask handling + hit decision for the probe kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cache_probe.cache_probe import probe_rhat

LANE = 128
SUBLANE = 8


@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_probe(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
                n_queries: jax.Array, epsilon,
                interpret: bool | None = None):
    """Fused LowQuality test. q_emb (Qmax, D); psi (D,); radius (Qmax,);
    n_queries scalar. Returns (hit, best_r_hat, best_idx)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qmax, d = q_emb.shape
    dpad = (-d) % LANE
    qpad = (-qmax) % SUBLANE
    q_emb_p = jnp.pad(q_emb, ((0, qpad), (0, dpad)))
    psi_p = jnp.pad(psi[None], ((0, SUBLANE - 1), (0, dpad)))
    valid = jnp.arange(qmax + qpad) < n_queries
    radius_m = jnp.where(valid, jnp.pad(radius, (0, qpad),
                                        constant_values=-jnp.inf), -jnp.inf)
    r_hat = probe_rhat(q_emb_p, psi_p, radius_m[:, None],
                       interpret=interpret)[:, 0]
    r_hat = jnp.where(valid, r_hat, -jnp.inf)
    best = jnp.argmax(r_hat)
    hit = jnp.logical_and(n_queries > 0, r_hat[best] >= epsilon)
    return hit, r_hat[best], jnp.where(n_queries > 0, best, -1)
