"""Pure-jnp oracle for the LowQuality probe (mirrors core.cache.probe)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def probe_ref(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
              n_queries: jax.Array, epsilon: float):
    """Returns (hit, best_r_hat, best_idx)."""
    valid = jnp.arange(q_emb.shape[0]) < n_queries
    dist = jnp.sqrt(jnp.clip(2.0 - 2.0 * (q_emb @ psi), 0.0, None))
    r_hat = jnp.where(valid, radius - dist, -jnp.inf)
    best = jnp.argmax(r_hat)
    hit = jnp.logical_and(n_queries > 0, r_hat[best] >= epsilon)
    return hit, r_hat[best], jnp.where(n_queries > 0, best, -1)
