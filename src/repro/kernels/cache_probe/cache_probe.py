"""Fused LowQuality-probe Pallas kernels (paper Eq. 3/4).

The probe runs on EVERY utterance, fused with the query encoder on the
serving chip: one (Qmax, D) x (D,) matvec on the MXU, the sqrt/subtract on
the VPU, emitting per-cached-query r_hat = r_a - delta(psi_a, psi).
Single-tile (Qmax <= 64 cached queries by the paper's design: one per cache
miss in a <=13-turn conversation), so the whole working set sits in VMEM.

Record embeddings may be stored quantized (``repro.core.quant``: bf16, or
int8 with an fp32 per-record scale): the payload is cast to f32 in VMEM and
the scale multiplies the score before the distance — the same score-side
rule as the corpus scan, so the kernel agrees with the jnp ref probe at any
storage dtype (the wrapper always passes a scale column, all-ones for
unquantized records; x * 1.0f is bit-exact).

Two entry points:

  * ``probe_rhat``         — one session (the original scalar kernel).
  * ``probe_rhat_batched`` — S sessions in ONE launch: grid over the
    session axis of a stacked cache, each step probing one (Qmax, D) record
    block against that session's psi.  This is the serving hot path for
    ``BatchedEngine`` waves — one kernel launch per wave instead of S
    matvecs, with the ring-buffer validity mask (slot < n_queries, where
    n_queries counts *total* records and the ring keeps the newest
    min(n_queries, Qmax)) already folded into the radius operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(q_emb_ref, psi_ref, radius_ref, scale_ref, out_ref):
    q = q_emb_ref[...].astype(jnp.float32)               # (Qmax, D)
    psi = psi_ref[...]                                   # (8, D) row 0 live
    scores = jax.lax.dot_general(
        q, psi, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (Qmax, 8)
    scores = scores[:, :1] * scale_ref[...]              # (Qmax, 1)
    dist = jnp.sqrt(jnp.clip(2.0 - 2.0 * scores, 0.0, None))
    out_ref[...] = radius_ref[...] - dist                # (Qmax, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_rhat(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
               scale: jax.Array, interpret: bool = False) -> jax.Array:
    """q_emb: (Qmax, D) unit rows (fp32 / bf16 / int8 payload); psi: (8, D)
    (row 0 = query); radius: (Qmax, 1) with -inf on empty slots; scale:
    (Qmax, 1) f32 per-record score multipliers. Returns r_hat (Qmax, 1)
    f32."""
    qmax, d = q_emb.shape
    return pl.pallas_call(
        _probe_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((qmax, d), lambda i: (0, 0)),
                  pl.BlockSpec((8, d), lambda i: (0, 0)),
                  pl.BlockSpec((qmax, 1), lambda i: (0, 0)),
                  pl.BlockSpec((qmax, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((qmax, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((qmax, 1), jnp.float32),
        interpret=interpret,
    )(q_emb, psi, radius, scale)


def _probe_batched_kernel(q_emb_ref, psi_ref, radius_ref, scale_ref, out_ref):
    q = q_emb_ref[0].astype(jnp.float32)                 # (Qmax, D)
    psi = psi_ref[0]                                     # (8, D) row 0 live
    scores = jax.lax.dot_general(
        q, psi, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (Qmax, 8)
    scores = scores[:, :1] * scale_ref[0]                # (Qmax, 1)
    dist = jnp.sqrt(jnp.clip(2.0 - 2.0 * scores, 0.0, None))
    out_ref[0] = radius_ref[0] - dist                    # (Qmax, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_rhat_batched(q_emb: jax.Array, psi: jax.Array, radius: jax.Array,
                       scale: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """One launch over a stacked cache. q_emb: (S, Qmax, D) unit rows (any
    storage dtype); psi: (S, 8, D) (row 0 = that session's query); radius:
    (S, Qmax, 1) with -inf on empty/invalid slots; scale: (S, Qmax, 1) f32
    per-record score multipliers. Returns r_hat (S, Qmax, 1) f32."""
    s, qmax, d = q_emb.shape
    return pl.pallas_call(
        _probe_batched_kernel,
        grid=(s,),
        in_specs=[pl.BlockSpec((1, qmax, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, 8, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, qmax, 1), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, qmax, 1), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, qmax, 1), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, qmax, 1), jnp.float32),
        interpret=interpret,
    )(q_emb, psi, radius, scale)
