"""Session-batched LowQuality cache-probe kernel (see ``.ops``)."""

from repro.kernels.cache_probe.ops import cache_probe  # noqa: F401
