"""Graph data: synthetic generators + a real layered neighbor sampler.

``minibatch_lg`` needs GraphSAGE-style fanout sampling (15-10) from a CSR
adjacency; the sampler is host-side numpy (the standard production split:
sampling on CPU hosts, compute on accelerators) and emits fixed-size padded
edge blocks so the jitted step has static shapes.  Padding convention:
``src < 0`` marks invalid edges (masked inside the model).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphData:
    node_feat: np.ndarray     # (N, F)
    coords: np.ndarray        # (N, 3)
    edge_index: np.ndarray    # (2, E)
    labels: np.ndarray        # (N,)


def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int = 8) -> GraphData:
    rng = np.random.default_rng(seed)
    # community structure so labels are learnable from features
    comm = rng.integers(0, n_classes, n_nodes)
    centers = rng.standard_normal((n_classes, d_feat))
    feat = centers[comm] + 0.5 * rng.standard_normal((n_nodes, d_feat))
    src = rng.integers(0, n_nodes, n_edges)
    # homophily: half the edges connect within-community nodes
    dst = np.where(rng.random(n_edges) < 0.5,
                   rng.integers(0, n_nodes, n_edges),
                   np.roll(src, 1))
    coords = rng.standard_normal((n_nodes, 3))
    return GraphData(feat.astype(np.float32), coords.astype(np.float32),
                     np.stack([src, dst]).astype(np.int32), comm.astype(np.int32))


def batched_molecules(seed: int, batch: int, n_nodes: int, n_edges: int,
                      d_feat: int, n_classes: int = 8):
    """Disjoint union of ``batch`` small graphs with offset edge indices."""
    rng = np.random.default_rng(seed)
    feats, coords, edges, gids, labels = [], [], [], [], []
    for g in range(batch):
        feats.append(rng.standard_normal((n_nodes, d_feat)))
        coords.append(rng.standard_normal((n_nodes, 3)))
        src = rng.integers(0, n_nodes, n_edges) + g * n_nodes
        dst = rng.integers(0, n_nodes, n_edges) + g * n_nodes
        edges.append(np.stack([src, dst]))
        gids.append(np.full(n_nodes, g))
        labels.append(rng.integers(0, n_classes))
    return (np.concatenate(feats).astype(np.float32),
            np.concatenate(coords).astype(np.float32),
            np.concatenate(edges, axis=1).astype(np.int32),
            np.concatenate(gids).astype(np.int32),
            np.asarray(labels, np.int32))


class NeighborSampler:
    """Layered (GraphSAGE) fanout sampler over a CSR adjacency."""

    def __init__(self, edge_index: np.ndarray, n_nodes: int):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        self.ptr = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def sample(self, seed_nodes: np.ndarray, fanouts, rng) -> np.ndarray:
        """Fixed-size padded edge block rooted at ``seed_nodes``: layer i
        contributes exactly |seeds| * prod(fanouts[:i+1]) edge slots (static
        shapes for the jitted step); src=-1 marks padding."""
        blocks = []
        frontier = np.asarray(seed_nodes, np.int64)
        slots = frontier.size
        for fan in fanouts:
            fpad = np.full(slots, -1, np.int64)
            fpad[:min(frontier.size, slots)] = frontier[:slots]
            srcs = np.full((slots, fan), -1, np.int64)
            for i, node in enumerate(fpad):
                if node < 0:
                    continue
                lo, hi = self.ptr[node], self.ptr[node + 1]
                deg = int(hi - lo)
                if deg == 0:
                    continue
                take = min(fan, deg)
                picks = rng.choice(deg, size=take, replace=deg < fan)
                srcs[i, :take] = self.nbr[lo + picks]
            dsts = np.broadcast_to(fpad[:, None], srcs.shape)
            valid = srcs >= 0
            blocks.append(np.stack([srcs.ravel(),
                                    np.where(valid, dsts, -1).ravel()]))
            nxt = np.unique(srcs[valid])
            frontier = nxt if nxt.size else frontier
            slots = slots * fan
        return np.concatenate(blocks, axis=1).astype(np.int32)
