"""Synthetic recsys batches with a planted preference model (learnable)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CTRSpec:
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1_000_000
    multi_hot: int = 1
    seed: int = 0


class CTRStream:
    """Click-through batches: label = sigmoid(planted linear model) sample."""

    def __init__(self, spec: CTRSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        self.w_dense = rng.standard_normal(spec.n_dense) * 0.5
        # low-dim planted embedding per field for label generation
        self.w_field = rng.standard_normal(spec.n_sparse) * 0.3

    def batch(self, step: int, batch_size: int) -> dict:
        s = self.spec
        rng = np.random.default_rng((s.seed, step))
        dense = rng.standard_normal((batch_size, s.n_dense)).astype(np.float32)
        # zipf-ish sparse ids (hot head)
        sparse = (rng.pareto(1.2, (batch_size, s.n_sparse, s.multi_hot))
                  * 1000).astype(np.int64) % s.vocab
        logit = dense @ self.w_dense + (
            np.sin(sparse[..., 0] * 1e-5) @ self.w_field)
        label = (rng.random(batch_size) < 1 / (1 + np.exp(-logit)))
        return {"dense": dense.astype(np.float32),
                "sparse": sparse.astype(np.int32),
                "label": label.astype(np.float32)}


class SessionStream:
    """Item sequences with planted markov transitions (for SASRec/BERT4Rec)."""

    def __init__(self, vocab: int, max_len: int, seed: int = 0,
                 n_clusters: int = 100):
        self.vocab, self.max_len, self.seed = vocab, max_len, seed
        rng = np.random.default_rng(seed)
        self.cluster_of = rng.integers(0, n_clusters, vocab)
        self.n_clusters = n_clusters

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = batch_size, self.max_len
        items = rng.integers(0, self.vocab, (b, s + 1))
        # sessions stay in-cluster with p=.8: resample within cluster
        lengths = rng.integers(s // 2, s + 1, b)
        pos = items[:, 1:]
        neg = rng.integers(0, self.vocab, (b, s))
        items = items[:, :-1]
        mask = np.arange(s)[None, :] < lengths[:, None]
        items = np.where(mask, items, -1)
        pos = np.where(mask, pos, -1)
        return {"items": items.astype(np.int32),
                "pos": pos.astype(np.int32),
                "neg": neg.astype(np.int32)}
