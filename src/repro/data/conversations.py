"""Synthetic CAsT-like workload with planted topical locality.

TREC CAsT qrels/collections are not redistributable offline, so we generate a
corpus + conversations that reproduce the *geometry* the paper exploits
(Fig. 1): queries of one conversation cluster tightly; their relevant
documents cluster around the same topic centroid; conversations drift within
a topic and occasionally shift sub-topic.

Everything is deterministic in the seed.  Embeddings are generated directly
in raw R^l space (pre-Eq.-1), with non-unit norms, so the MIPS->L2 transform
is exercised end to end.

Relevance (qrels): for each utterance, the graded relevant set is the docs
nearest the utterance's *ideal point* (its noise-free topical position):
grade 2 for the closest ``n_rel2``, grade 1 for the next ``n_rel1``.  The
no-caching system does not see ideal points — only the noisy utterance — so
effectiveness < 1 and cache-induced degradation is measurable, mirroring the
paper's evaluation design.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

__all__ = ["WorldConfig", "Conversation", "TopicWorld", "make_world"]


@dataclass(frozen=True)
class WorldConfig:
    n_topics: int = 20
    docs_per_topic: int = 2000
    n_background: int = 20000       # off-topic distractor docs
    dim: int = 768                  # raw dim (pre-transform), STAR-like
    subspace_dim: int = 16          # local manifold dim per topic (see note)
    doc_sigma: float = 0.35         # doc spread around topic center
    query_sigma: float = 0.12       # utterance noise around ideal point
    drift_sigma: float = 0.08       # per-turn topical drift
    subtopic_prob: float = 0.25     # prob. a turn jumps to a new sub-topic
    subtopic_sigma: float = 0.45    # sub-topic offset scale
    turns: int = 10
    n_conversations: int = 30
    n_rel2: int = 5
    n_rel1: int = 15
    norm_jitter: float = 0.15       # doc norms in [1-j, 1+j] (exercises Eq. 1)
    seed: int = 0


@dataclass
class Conversation:
    topic: int
    queries: np.ndarray          # (turns, dim) raw query embeddings
    ideal_points: np.ndarray     # (turns, dim) noise-free positions
    qrels: List[dict]            # per turn: {doc_id: grade}


@dataclass
class TopicWorld:
    cfg: WorldConfig
    doc_emb: np.ndarray          # (n_docs, dim) raw
    doc_topic: np.ndarray        # (n_docs,) topic id, -1 = background
    centers: np.ndarray          # (n_topics, dim) unit
    conversations: List[Conversation]

    @property
    def n_docs(self) -> int:
        return self.doc_emb.shape[0]


def _unit(x: np.ndarray, axis=-1) -> np.ndarray:
    return x / np.linalg.norm(x, axis=axis, keepdims=True)


def _noise(rng, shape, sigma: float) -> np.ndarray:
    """Gaussian with TOTAL norm ~= sigma (not per-coordinate): in d dims a
    per-coordinate sigma yields norm sigma*sqrt(d), which at d=768 drowns
    the unit-norm signal — all sigmas in WorldConfig are norm-scale."""
    return (sigma / np.sqrt(shape[-1])) * rng.standard_normal(shape)


def make_world(cfg: WorldConfig = WorldConfig()) -> TopicWorld:
    """Topical-locality world.

    Within-topic structure lives in a per-topic low-dim subspace
    (``subspace_dim``): isotropic 768-d Gaussians have vanishing angular
    discrimination between near neighbors (O(sigma^2/sqrt(d))), so ranking
    would be dominated by norm jitter — real encoder embeddings are locally
    low-rank, which this reproduces.  All sigmas are total-norm scales.
    """
    rng = np.random.default_rng(cfg.seed)
    centers = _unit(rng.standard_normal((cfg.n_topics, cfg.dim)))
    # per-topic orthonormal local frames (dim x subspace_dim)
    frames = []
    for t in range(cfg.n_topics):
        m = rng.standard_normal((cfg.dim, cfg.subspace_dim))
        q, _ = np.linalg.qr(m)
        frames.append(q)
    frames = np.stack(frames)

    def in_subspace(topic, shape, sigma):
        z = rng.standard_normal(shape + (cfg.subspace_dim,))
        z *= sigma / np.sqrt(cfg.subspace_dim)
        return z @ frames[topic].T

    # --- corpus ----------------------------------------------------------
    topic_docs = np.concatenate([
        _unit(centers[t] + in_subspace(t, (cfg.docs_per_topic,),
                                       cfg.doc_sigma))
        for t in range(cfg.n_topics)])
    bg_docs = _unit(rng.standard_normal((cfg.n_background, cfg.dim)))
    doc_emb = np.concatenate([topic_docs, bg_docs], axis=0)
    # non-unit norms so Eq. 1's document branch is non-trivial
    norms = 1.0 + cfg.norm_jitter * (rng.random(doc_emb.shape[0]) * 2 - 1)
    doc_emb = doc_emb * norms[:, None]
    doc_topic = np.concatenate([
        np.repeat(np.arange(cfg.n_topics), cfg.docs_per_topic),
        np.full(cfg.n_background, -1),
    ])

    # normalized docs for qrel geometry (relevance ~ angular proximity)
    doc_unit = _unit(doc_emb)

    # --- conversations ----------------------------------------------------
    convs: List[Conversation] = []
    for _ in range(cfg.n_conversations):
        topic = int(rng.integers(cfg.n_topics))
        point = _unit(centers[topic] +
                      in_subspace(topic, (), cfg.doc_sigma * 0.5))
        queries, ideals, qrels = [], [], []
        for _t in range(cfg.turns):
            if _t > 0 and rng.random() < cfg.subtopic_prob:
                point = _unit(centers[topic] +
                              in_subspace(topic, (), cfg.subtopic_sigma))
            point = _unit(point + in_subspace(topic, (), cfg.drift_sigma))
            q = point + in_subspace(topic, (), cfg.query_sigma)
            sims = doc_unit @ point
            order = np.argsort(-sims)
            qr = {int(d): 2 for d in order[:cfg.n_rel2]}
            qr.update({int(d): 1 for d in order[cfg.n_rel2:cfg.n_rel2 + cfg.n_rel1]})
            queries.append(q)
            ideals.append(point.copy())
            qrels.append(qr)
        convs.append(Conversation(topic=topic,
                                  queries=np.stack(queries),
                                  ideal_points=np.stack(ideals),
                                  qrels=qrels))
    return TopicWorld(cfg=cfg, doc_emb=doc_emb, doc_topic=doc_topic,
                      centers=centers, conversations=convs)
