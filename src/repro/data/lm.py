"""Deterministic synthetic LM token pipeline.

Markov-chain tokens (not uniform noise) so the CE loss is learnable and a
few-hundred-step training run shows a real loss curve.  Multi-host aware:
each process materializes only its slice of the global batch.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LMBatchSpec:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


class TokenStream:
    """Stateless per-step batches: batch(step) is reproducible and identical
    across restarts — the checkpoint only needs to store the step counter
    (fault-tolerant data pipeline with zero state)."""

    def __init__(self, spec: LMBatchSpec, n_states: int = 64,
                 process_index: int = 0, process_count: int = 1):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        # sparse-ish Markov transition over a small state space mapped to vocab
        self.proj = rng.integers(0, spec.vocab_size, n_states).astype(np.int32)
        trans = rng.dirichlet(np.full(n_states, 0.3), size=n_states)
        self.trans_cum = np.cumsum(trans, axis=1).astype(np.float32)
        self.n_states = n_states
        assert spec.global_batch % process_count == 0
        self.local_batch = spec.global_batch // process_count
        self.process_index = process_index

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.spec.seed, step, self.process_index))
        b, s = self.local_batch, self.spec.seq_len
        u = rng.random((b, s + 1), dtype=np.float32)
        states = np.zeros((b, s + 1), np.int32)
        states[:, 0] = rng.integers(0, self.n_states, b)
        for t in range(1, s + 1):
            states[:, t] = np.argmax(
                u[:, t][:, None] < self.trans_cum[states[:, t - 1]], axis=1)
        tokens = self.proj[states]
        return {"tokens": jnp.asarray(tokens[:, :-1]),
                "labels": jnp.asarray(tokens[:, 1:])}
