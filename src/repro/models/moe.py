"""Mixture-of-Experts FFN: top-k routing + capacity-bucketed dispatch.

Expert-parallel-friendly: the (E, C, d) dispatch buffer is sharded over the
"model" mesh axis (expert parallelism) so the token scatter lowers to an
all-to-all; expert weights are additionally FSDP-sharded over "data".

Routing: softmax top-k with optional normalization of the selected gates
(DeepSeek style) and a Switch/GShard auxiliary load-balancing loss.  Shared
experts (DeepSeek) run densely next to the routed path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_ff: int                    # per routed expert
    n_shared: int = 0
    d_ff_shared: int = 0         # defaults to d_ff * n_shared when 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 1e-3
    norm_topk: bool = True       # renormalize selected gates (DeepSeek)
    router_dtype: object = jnp.float32


def init_moe(key: jax.Array, cfg: MoEConfig, d_model: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    scale_in = d_model ** -0.5
    scale_out = cfg.d_ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, cfg.n_experts),
                                    jnp.float32) * scale_in,
        "wi": jax.random.normal(ks[1], (cfg.n_experts, d_model, 2 * cfg.d_ff),
                                dtype) * scale_in,
        "wo": jax.random.normal(ks[2], (cfg.n_experts, cfg.d_ff, d_model),
                                dtype) * scale_out,
    }
    if cfg.n_shared:
        dff_s = cfg.d_ff_shared or cfg.d_ff * cfg.n_shared
        p["shared_wi"] = jax.random.normal(ks[3], (d_model, 2 * dff_s),
                                           dtype) * scale_in
        p["shared_wo"] = jax.random.normal(ks[4], (dff_s, d_model),
                                           dtype) * dff_s ** -0.5
    return p


def _swiglu(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    h = x @ wi
    gate, up = jnp.split(h, 2, axis=-1)
    return (jax.nn.silu(gate) * up) @ wo


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig,
            capacity: Optional[int] = None) -> MoEOut:
    """x: (T, d) token-major. Returns combined output + aux loss."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if capacity is None:
        capacity = int(t * k / e * cfg.capacity_factor) + 1
    # pad capacity to a friendly multiple for the batched expert matmul
    capacity = max(8, -(-capacity // 8) * 8)

    logits = (x.astype(cfg.router_dtype) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                   # (T, K)
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balancing loss (Switch/GShard) ----
    me = probs.mean(axis=0)                                            # (E,)
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)          # (T,K,E)
    ce = onehot.sum(axis=(0, 1)) / (t * k)                             # fraction
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)

    # ---- capacity-bucketed dispatch ----
    # position of each (token, choice) in its expert's queue
    flat_ids = expert_ids.reshape(-1)                                  # (T*K,)
    flat_oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)             # (T*K,E)
    pos_in_e = (jnp.cumsum(flat_oh, axis=0) - 1)                       # (T*K,E)
    pos = jnp.take_along_axis(pos_in_e, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < capacity
    gates = gate_vals.reshape(-1) * keep

    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    safe_pos = jnp.where(keep, pos, capacity)                          # OOB drop
    buf = buf.at[flat_ids, safe_pos].add(x[tok_idx], mode="drop")
    buf = constrain(buf, "moe_buf")     # EP: experts over "model" (all-to-all)

    # ---- expert compute: batched over E (shardable over "model") ----
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    h = constrain(h, "moe_hidden")
    gate_h, up_h = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate_h) * up_h
    out_buf = jnp.einsum("ecf,efd->ecd", act, params["wo"])
    out_buf = constrain(out_buf, "moe_buf")

    # ---- combine ----
    gathered = out_buf[flat_ids, safe_pos]                              # (T*K, d)
    y = jnp.zeros((t, d), x.dtype).at[tok_idx].add(
        gathered * gates[:, None].astype(x.dtype))
    y = constrain(y, "moe_out")

    if "shared_wi" in params:
        y = y + _swiglu(x, params["shared_wi"], params["shared_wo"])
    return MoEOut(y, aux.astype(jnp.float32))


def moe_ffn_sharded(params: dict, x: jax.Array, cfg: MoEConfig, mesh,
                    capacity: Optional[int] = None) -> MoEOut:
    """Expert-parallel MoE via shard_map (EP over "model", DP over the rest).

    GSPMD replicates data-dependent scatters, so the jnp-level dispatch in
    ``moe_ffn`` silently loses expert parallelism under pjit (verified in
    the dry-run: per-device flops == global flops).  Here the dispatch is
    *per-device code*: tokens are sharded over the data axes and replicated
    over "model"; every model-rank routes the same local tokens but keeps
    only assignments that land in its own expert slice, runs its local
    (E/TP) experts, and the partial combines are psum'd over "model" —
    Megatron-style EP+TP hybrid with no all-to-all (the psum replaces it;
    an a2a variant is a recorded §Perf candidate).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.api import data_axes

    dp = tuple(data_axes(mesh))
    tp = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.top_k
    assert e % tp == 0, f"experts {e} not divisible by model axis {tp}"
    e_loc = e // tp
    t = x.shape[0]
    t_loc = t // _axis_prod(mesh, dp)
    if capacity is None:
        capacity = int(t_loc * k / e * cfg.capacity_factor) + 1
    capacity = max(8, -(-capacity // 8) * 8)

    def local_fn(x, router, wi, wo):
        # x: (t_loc, d) — same on every model-rank; wi/wo: local expert slice
        rank = jax.lax.axis_index("model")
        e0 = rank * e_loc
        # route in the activation dtype (f32 cotangents of a pref-f32 dot
        # were a dominant backward temp); softmax still runs in f32.
        logits = (x @ router.astype(x.dtype)).astype(cfg.router_dtype)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        if cfg.norm_topk:
            gate_vals = gate_vals / jnp.maximum(
                gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        onehot_f = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
        ce = onehot_f.sum(axis=(0, 1)) / (x.shape[0] * k)
        aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        # position of each (token, choice) in its expert's queue — computed
        # on a transposed (K, T, E) layout then flattened back
        flat_ids = expert_ids.reshape(-1)
        flat_oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(flat_oh, axis=0) - 1
        pos = jnp.take_along_axis(pos_in_e, flat_ids[:, None], axis=1)[:, 0]
        mine = jnp.logical_and(flat_ids >= e0, flat_ids < e0 + e_loc)
        keep = jnp.logical_and(pos < capacity, mine)

        eid_k = jnp.where(keep, flat_ids - e0, e_loc).reshape(-1, k)
        pos_k = jnp.where(keep, pos, capacity).reshape(-1, k)
        gates_k = (gate_vals.reshape(-1) * keep).reshape(-1, k)

        # dispatch/combine one routing choice at a time: K scatters/gathers
        # of (T_loc, d) instead of one (T_loc*K, d) gather — the big-gather
        # residual was the dominant per-layer temp in the dry-run.
        buf = jnp.zeros((e_loc, capacity, x.shape[1]), x.dtype)
        for kk in range(k):
            buf = buf.at[eid_k[:, kk], pos_k[:, kk]].add(
                x * (gates_k[:, kk] > 0)[:, None].astype(x.dtype),
                mode="drop")

        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        gate_h, up_h = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate_h) * up_h
        out_buf = jnp.einsum("ecf,efd->ecd", act, wo)

        y = jnp.zeros_like(x)
        for kk in range(k):
            got = out_buf[eid_k[:, kk].clip(0, e_loc - 1),
                          pos_k[:, kk].clip(0, capacity - 1)]
            y = y + got * gates_k[:, kk][:, None].astype(x.dtype)
        y = jax.lax.psum(y, "model")        # combine expert partials (EP)
        return y, aux

    sharded = shard_map(
        jax.checkpoint(local_fn, prevent_cse=False), mesh=mesh,
        in_specs=(P(dp if dp else None, None), P(None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp if dp else None, None), P()),
        check_rep=False)
    y, aux = sharded(x, params["router"], params["wi"], params["wo"])
    if "shared_wi" in params:
        y = y + _swiglu(x, params["shared_wi"], params["shared_wo"])
    return MoEOut(y, aux.astype(jnp.float32))


def _axis_prod(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out
