"""Config-driven LM transformer family.

One implementation covers the five assigned LM architectures:
  * GQA attention (chatglm3 kv=2, mistral kv=8, gemma2 kv=8, llama4 kv=8)
  * MLA latent attention + MTP head (deepseek-v3)
  * MoE FFN with shared experts (deepseek 256e top-8 + 1 shared,
    llama4-scout 16e top-1 + shared), dense-first-k layers
  * RoPE (full / half "2d" chatglm style, interleaved), per-layer
    local/global window schedules + logit softcaps (gemma2)

Layers are scanned (``lax.scan`` over stacked params, grouped dense-vs-moe)
with configurable remat, so HLO size and activation memory stay O(1 layer).
Activation shardings are *logical* (``dist.api.constrain``) and resolved by
the launcher for whatever mesh is active.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.models import common as cm
from repro.models.moe import MoEConfig, init_moe, moe_ffn

# --------------------------------------------------------------- configs

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    attention: str = "gqa"                  # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    rope_theta: float = 1e4
    rotary_frac: float = 1.0                # 0.5 => chatglm partial rotary
    rope_interleaved: bool = False
    window: Optional[int] = None
    layer_pattern: Optional[str] = None     # cycled, e.g. "lg" (gemma2)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0                 # leading dense layers when MoE
    mtp: bool = False                       # deepseek multi-token prediction
    mtp_weight: float = 0.3
    norm_eps: float = 1e-6
    use_post_norm: bool = False             # gemma2 pre+post norms
    zero_centered_norm: bool = False        # gemma-style (1 + w)
    embed_scale: bool = False               # multiply embeddings by sqrt(d)
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    q_chunk: int = 512
    kv_chunk: int = 1024
    # calibration-only knobs: unroll scans so XLA cost_analysis counts every
    # trip (while bodies are otherwise counted once) — see launch/calibrate.
    attn_unroll: bool = False
    layer_unroll: bool = False

    @property
    def head_dim(self) -> int:
        if self.attention == "mla":
            m = self.mla or MLAConfig()
            return m.qk_nope_dim + m.qk_rope_dim
        return self.d_head or self.d_model // self.n_heads

    @property
    def v_head_dim(self) -> int:
        if self.attention == "mla":
            return (self.mla or MLAConfig()).v_head_dim
        return self.d_head or self.d_model // self.n_heads

    def layer_groups(self):
        """[(kind, count)] — dense-prefix then MoE remainder."""
        if self.moe is None:
            return [("dense", self.n_layers)]
        nd = self.n_dense_layers
        out = []
        if nd:
            out.append(("dense", nd))
        out.append(("moe", self.n_layers - nd))
        return out

    def window_schedule(self) -> jnp.ndarray:
        """Per-layer window sizes; 0 = unlimited (global)."""
        if self.layer_pattern is None:
            w = self.window or 0
            return jnp.full((self.n_layers,), w, jnp.int32)
        pat = (self.layer_pattern * self.n_layers)[: self.n_layers]
        return jnp.asarray([(self.window or 0) if c == "l" else 0 for c in pat],
                           jnp.int32)


# ------------------------------------------------------------ param init

def _init_attn(key, cfg: TransformerConfig) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.head_dim
    s = d ** -0.5
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        ks = jax.random.split(key, 8)
        dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
        return {
            "wdq": jax.random.normal(ks[0], (d, m.q_lora_rank), cfg.dtype) * s,
            "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
            "wuq": jax.random.normal(ks[1], (m.q_lora_rank, h * (dn + dr)),
                                     cfg.dtype) * m.q_lora_rank ** -0.5,
            "wdkv": jax.random.normal(ks[2], (d, m.kv_lora_rank), cfg.dtype) * s,
            "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
            "wkr": jax.random.normal(ks[3], (d, dr), cfg.dtype) * s,
            "wuk": jax.random.normal(ks[4], (m.kv_lora_rank, h * dn),
                                     cfg.dtype) * m.kv_lora_rank ** -0.5,
            "wuv": jax.random.normal(ks[5], (m.kv_lora_rank, h * dv),
                                     cfg.dtype) * m.kv_lora_rank ** -0.5,
            "wo": jax.random.normal(ks[6], (h * dv, d), cfg.dtype)
                  * (h * dv) ** -0.5 / (2 * cfg.n_layers) ** 0.5,
        }
    ks = jax.random.split(key, 4)
    return {
        "wq": jax.random.normal(ks[0], (d, h * dh), cfg.dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv * dh), cfg.dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv * dh), cfg.dtype) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), cfg.dtype)
              * (h * dh) ** -0.5 / (2 * cfg.n_layers) ** 0.5,
    }


def _init_layer(key, cfg: TransformerConfig, kind: str) -> dict:
    k_attn, k_ffn = jax.random.split(key)
    p = {"attn": _init_attn(k_attn, cfg),
         "pre_attn_norm": jnp.zeros((cfg.d_model,), jnp.float32)
         if cfg.zero_centered_norm else jnp.ones((cfg.d_model,), jnp.float32)}
    one = jnp.zeros if cfg.zero_centered_norm else jnp.ones
    p["pre_ffn_norm"] = one((cfg.d_model,), jnp.float32)
    if cfg.use_post_norm:
        p["post_attn_norm"] = one((cfg.d_model,), jnp.float32)
        p["post_ffn_norm"] = one((cfg.d_model,), jnp.float32)
    if kind == "moe":
        p["ffn"] = init_moe(k_ffn, cfg.moe, cfg.d_model, cfg.dtype)
    else:
        k1, k2 = jax.random.split(k_ffn)
        p["ffn"] = {
            "wi": jax.random.normal(k1, (cfg.d_model, 2 * cfg.d_ff), cfg.dtype)
                  * cfg.d_model ** -0.5,
            "wo": jax.random.normal(k2, (cfg.d_ff, cfg.d_model), cfg.dtype)
                  * cfg.d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5,
        }
    return p


def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    keys = jax.random.split(key, 4)
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                   cfg.dtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.zero_centered_norm else jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), cfg.dtype) * cfg.d_model ** -0.5
    gkey = keys[2]
    for gi, (kind, count) in enumerate(cfg.layer_groups()):
        gkey, sub = jax.random.split(gkey)
        lkeys = jax.random.split(sub, count)
        params[f"group{gi}_{kind}"] = jax.vmap(
            lambda k: _init_layer(k, cfg, kind))(lkeys)
    if cfg.mtp:
        k1, k2 = jax.random.split(keys[3])
        params["mtp"] = {
            "proj": jax.random.normal(k1, (2 * cfg.d_model, cfg.d_model),
                                      cfg.dtype) * (2 * cfg.d_model) ** -0.5,
            "block": _init_layer(k2, cfg, "dense"),
            "norm_h": jnp.ones((cfg.d_model,), jnp.float32),
            "norm_e": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: TransformerConfig, params) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    m = cfg.moe
    routed_per_layer = m.n_experts * (cfg.d_model * 2 * m.d_ff + m.d_ff * cfg.d_model)
    n_moe = cfg.n_layers - cfg.n_dense_layers
    inactive = n_moe * routed_per_layer * (1 - m.top_k / m.n_experts)
    return int(total - inactive)


# ------------------------------------------------------------- attention

def _attn_gqa(p: dict, x: jax.Array, positions: jax.Array, window,
              cfg: TransformerConfig, kv_caches=None, cur_len=None):
    """Returns (out, (k, v)) — k/v for cache building, or attends against
    kv_caches (decode) when given."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    q = cm.apply_rope(q, positions, cfg.rope_theta, cfg.rotary_frac,
                      cfg.rope_interleaved)
    k = cm.apply_rope(k, positions, cfg.rope_theta, cfg.rotary_frac,
                      cfg.rope_interleaved)
    q = constrain(q, "act_bshd")
    k = constrain(k, "act_bskd")
    v = constrain(v, "act_bskd")
    if kv_caches is not None:
        k_cache, v_cache = kv_caches
        pos = jnp.asarray(cur_len - 1, jnp.int32)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
        k_cache = constrain(k_cache, "kv_cache")
        v_cache = constrain(v_cache, "kv_cache")
        o = cm.decode_attention(q, k_cache, v_cache, cur_len, window=window,
                                logit_cap=cfg.attn_softcap)
        new_cache = (k_cache, v_cache)
    else:
        o = cm.blockwise_attention(q, k, v, causal=True, window=window,
                                   q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                   logit_cap=cfg.attn_softcap,
                                   unroll=cfg.attn_unroll)
        new_cache = (k, v)
    o = constrain(o, "act_bshd")
    out = o.reshape(b, s, h * dh) @ p["wo"]
    return out, new_cache


def _attn_mla(p: dict, x: jax.Array, positions: jax.Array, window,
              cfg: TransformerConfig, kv_caches=None, cur_len=None):
    """MLA: latent-compressed KV. Train path up-projects (faithful); decode
    path uses the absorbed formulation against the latent cache."""
    m = cfg.mla or MLAConfig()
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank

    cq = cm.rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    qall = (cq @ p["wuq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = qall[..., :dn], qall[..., dn:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = cm.rms_norm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (b,s,r)
    kr = cm.apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]                  # (b,s,dr)
    scale = (dn + dr) ** -0.5

    if kv_caches is not None:
        ckv_cache, kr_cache = kv_caches
        pos = jnp.asarray(cur_len - 1, jnp.int32)
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, ckv, pos, 1)
        kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr, pos, 1)
        ckv_cache = constrain(ckv_cache, "mla_cache")
        kr_cache = constrain(kr_cache, "mla_cache_r")
        # absorbed attention: score via latent space, O(S*r) per head
        wuk = p["wuk"].reshape(r, h, dn)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)           # (b,1,h,r)
        s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                           ckv_cache.astype(jnp.float32))
        s_rope = jnp.einsum("bqhe,bse->bhqs", q_rope.astype(jnp.float32),
                            kr_cache.astype(jnp.float32))
        sc = (s_lat + s_rope) * scale
        spos = jnp.arange(ckv_cache.shape[1])
        valid = spos[None, :] < cur_len.reshape(-1, 1)
        sc = jnp.where(valid[:, None, None, :], sc, cm.NEG_INF)
        pr = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", pr,
                           ckv_cache.astype(jnp.float32))
        wuv = p["wuv"].reshape(r, h, dv)
        o = jnp.einsum("bqhr,rhv->bqhv", o_lat, wuv.astype(jnp.float32))
        new_cache = (ckv_cache, kr_cache)
    else:
        k_nope = (ckv @ p["wuk"]).reshape(b, s, h, dn)
        vfull = (ckv @ p["wuv"]).reshape(b, s, h, dv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, dr))], axis=-1)
        q = constrain(q, "act_bshd")
        k = constrain(k, "act_bshd")
        vfull = constrain(vfull, "act_bshd")
        o = cm.blockwise_attention(q, k, vfull, causal=True, window=window,
                                   q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                   logit_cap=cfg.attn_softcap, scale=scale,
                                   unroll=cfg.attn_unroll)
        new_cache = (ckv, kr)
    o = constrain(o.astype(x.dtype), "act_bshd")
    out = o.reshape(b, s, h * dv) @ p["wo"]
    return out, new_cache


def _attention(p, x, positions, window, cfg, kv_caches=None, cur_len=None):
    fn = _attn_mla if cfg.attention == "mla" else _attn_gqa
    return fn(p, x, positions, window, cfg, kv_caches, cur_len)


# ----------------------------------------------------------------- block

def _dense_ffn(p: dict, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    h = constrain(h, "act_bsf")
    return h @ p["wo"]


def _block(p: dict, x: jax.Array, positions, window, cfg: TransformerConfig,
           kind: str, kv_caches=None, cur_len=None):
    norm = functools.partial(cm.rms_norm, eps=cfg.norm_eps,
                             zero_centered=cfg.zero_centered_norm)
    a_in = norm(x, p["pre_attn_norm"])
    a_out, new_cache = _attention(p["attn"], a_in, positions, window, cfg,
                                  kv_caches, cur_len)
    if cfg.use_post_norm:
        a_out = norm(a_out, p["post_attn_norm"])
    x = constrain(x + a_out, "act_bsd")

    f_in = norm(x, p["pre_ffn_norm"])
    if kind == "moe":
        from repro.dist.api import active_mesh
        from repro.models.moe import moe_ffn_sharded
        b, s, d = f_in.shape
        mesh = active_mesh()
        dp_prod = 1
        if mesh is not None:
            for ax in mesh.axis_names:
                if ax != "model":
                    dp_prod *= mesh.shape[ax]
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.moe.n_experts % mesh.shape["model"] == 0 \
                and mesh.devices.size > 1 \
                and (b * s) % dp_prod == 0:  # tiny decode batches: GSPMD path
            out = moe_ffn_sharded(p["ffn"], f_in.reshape(b * s, d), cfg.moe,
                                  mesh)
        else:
            out = moe_ffn(p["ffn"], f_in.reshape(b * s, d), cfg.moe)
        f_out, aux = out.y.reshape(b, s, d), out.aux_loss
    else:
        f_out, aux = _dense_ffn(p["ffn"], f_in), jnp.zeros((), jnp.float32)
    if cfg.use_post_norm:
        f_out = norm(f_out, p["post_ffn_norm"])
    x = constrain(x + f_out, "act_bsd")
    return x, aux, new_cache


# --------------------------------------------------------------- forward

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def forward(params: dict, tokens: jax.Array, cfg: TransformerConfig, *,
            return_kv: bool = False, kv_len: Optional[int] = None,
            remat: str = "full"):
    """Causal forward pass (training / prefill).

    Returns (logits, aux_loss, hidden, kv_caches_per_group).
    kv caches (when return_kv) are written into (count, B, kv_len, ...) bufs.
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    x = constrain(x, "act_bsd")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    windows = cfg.window_schedule()
    kv_len = kv_len or s

    policy = REMAT_POLICIES[remat]
    total_aux = jnp.zeros((), jnp.float32)
    caches = []
    base = 0
    for gi, (kind, count) in enumerate(cfg.layer_groups()):
        stack = params[f"group{gi}_{kind}"]
        win_g = jax.lax.dynamic_slice_in_dim(windows, base, count)

        def body(x, scanned, kind=kind):
            p, win = scanned
            x, aux, kv = _block(p, x, positions, win, cfg, kind)
            if return_kv:
                pad = [(0, 0), (0, kv_len - s)] + [(0, 0)] * (kv[0].ndim - 2)
                kv = tuple(jnp.pad(c, pad) for c in kv)
                names = (("mla_cache", "mla_cache_r") if cfg.attention == "mla"
                         else ("kv_cache", "kv_cache"))
                kv = tuple(constrain(c, n) for c, n in zip(kv, names))
                return x, (aux, kv)
            return x, (aux, None)

        fn = body if policy is None and remat == "none" else jax.checkpoint(
            body, policy=policy, prevent_cse=False)
        x, (auxes, kv) = jax.lax.scan(fn, x, (stack, win_g),
                                      unroll=count if cfg.layer_unroll else 1)
        total_aux = total_aux + auxes.sum()
        caches.append(kv)
        base += count

    hidden = cm.rms_norm(x, params["final_norm"], cfg.norm_eps,
                         zero_centered=cfg.zero_centered_norm)
    logits = _head(params, hidden, cfg)
    return logits, total_aux, hidden, (caches if return_kv else None)


def _head(params, hidden, cfg: TransformerConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden @ w.astype(cfg.dtype)
    logits = cm.softcap(logits, cfg.final_softcap)
    return constrain(logits, "logits")


def mtp_logits(params: dict, tokens: jax.Array, hidden: jax.Array,
               cfg: TransformerConfig):
    """DeepSeek-style MTP (depth 1): predict token t+2 from hidden_t and
    embedding of token t+1."""
    p = params["mtp"]
    b, s = tokens.shape
    emb_next = constrain(params["embed"][tokens].astype(cfg.dtype),
                         "act_bsd")                          # teacher-forced t+1
    hidden = constrain(hidden, "act_bsd")
    h = jnp.concatenate([
        cm.rms_norm(hidden, p["norm_h"], cfg.norm_eps),
        cm.rms_norm(emb_next, p["norm_e"], cfg.norm_eps)], axis=-1) @ p["proj"]
    h = constrain(h, "act_bsd")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, _aux, _kv = _block(p["block"], h, positions, 0, cfg, "dense")
    return _head(params, cm.rms_norm(h, params["final_norm"], cfg.norm_eps), cfg)


# ----------------------------------------------------------------- decode

def init_kv_caches(cfg: TransformerConfig, batch: int, max_len: int):
    """Per-group stacked decode caches."""
    caches = []
    for kind, count in cfg.layer_groups():
        if cfg.attention == "mla":
            m = cfg.mla or MLAConfig()
            caches.append((
                jnp.zeros((count, batch, max_len, m.kv_lora_rank), cfg.dtype),
                jnp.zeros((count, batch, max_len, m.qk_rope_dim), cfg.dtype)))
        else:
            shape = (count, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            caches.append((jnp.zeros(shape, cfg.dtype),
                           jnp.zeros(shape, cfg.dtype)))
    return caches


def decode_step(params: dict, token: jax.Array, caches, cur_len: jax.Array,
                cfg: TransformerConfig):
    """One token for the whole batch. token: (B,) int32; cur_len: scalar
    (sequence length *including* this token). Returns (logits, new_caches)."""
    b = token.shape[0]
    x = params["embed"][token][:, None, :].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    positions = jnp.broadcast_to(cur_len - 1, (b, 1)).astype(jnp.int32)
    windows = cfg.window_schedule()
    cur = jnp.asarray(cur_len, jnp.int32)  # scalar — aligned batch decode

    new_caches = []
    base = 0
    for gi, (kind, count) in enumerate(cfg.layer_groups()):
        stack = params[f"group{gi}_{kind}"]
        win_g = jax.lax.dynamic_slice_in_dim(windows, base, count)

        def body(x, scanned, kind=kind):
            p, win, kv = scanned
            x, _aux, new_kv = _block(p, x, positions, win, cfg, kind,
                                     kv_caches=kv, cur_len=cur)
            return x, new_kv

        x, kv_out = jax.lax.scan(body, x, (stack, win_g, caches[gi]),
                                 unroll=count if cfg.layer_unroll else 1)
        new_caches.append(kv_out)
        base += count

    hidden = cm.rms_norm(x, params["final_norm"], cfg.norm_eps,
                         zero_centered=cfg.zero_centered_norm)
    return _head(params, hidden, cfg)[:, 0], new_caches
