"""RecSys architectures: DLRM-RM2, xDeepFM, SASRec, BERT4Rec.

Shared substrate: a multi-field EmbeddingBag over row-sharded tables (the
hot path — see kernels/embedding_bag) + per-model feature interaction.

Retrieval scoring (``retrieval_cand``): every model exposes a *query tower*
returning a user/session embedding, scored against 10^6 candidate item
embeddings with a batched MIPS — exactly the paper's metric-index scan, so
the CACHE front-end applies directly (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.models import common as cm


# ------------------------------------------------------------ embeddings

def field_pool(tables: jax.Array, idx: jax.Array, mode: str = "sum",
               use_kernel: bool = False) -> jax.Array:
    """tables: (F, V, D) stacked per-field tables; idx: (B, F, L) multi-hot
    (single-hot when L == 1); -> (B, F, D) pooled per field.

    On TPU (use_kernel) fields are flattened into one (F*V, D) table for a
    single embedding-bag kernel pass.  The distributed/jnp path gathers
    per-field via vmap WITHOUT reshaping: merging the unsharded field dim
    into the vocab-sharded dim forces GSPMD to rematerialize the whole
    table (measured: the full 6.7 GB DLRM table gathered per step)."""
    f, v, d = tables.shape
    b, f2, l = idx.shape
    assert f == f2
    if use_kernel:
        offset = (jnp.arange(f, dtype=jnp.int32) * v)[None, :, None]
        flat_idx = jnp.where(idx >= 0, idx + offset, -1).reshape(b * f, l)
        flat_tab = tables.reshape(f * v, d)
        out = embedding_bag(flat_tab, flat_idx, mode=mode,
                            use_kernel=use_kernel)
        return out.reshape(b, f, d)
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    rows = jax.vmap(lambda tab, ix: tab[ix], in_axes=(0, 1),
                    out_axes=1)(tables, safe)            # (B, F, L, D)
    rows = rows * valid[..., None]
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
        return rows.sum(axis=2) / cnt
    if mode == "max":
        masked = jnp.where(valid[..., None], rows, -jnp.inf)
        out = masked.max(axis=2)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    return rows.sum(axis=2)


def _mlp_init(key, sizes, dtype):
    ks = jax.random.split(key, len(sizes) - 1)
    return [{"w": jax.random.normal(k, (a, b), dtype) * (2.0 / a) ** 0.5,
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def _mlp(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


# ------------------------------------------------------------------ DLRM

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab: int = 1_000_000
    multi_hot: int = 1
    bot_mlp: tuple = (13, 512, 256, 64)
    top_mlp_hidden: tuple = (512, 512, 256, 1)
    dtype: object = jnp.float32


def dlrm_init(key: jax.Array, cfg: DLRMConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_pairs = (cfg.n_sparse + 1) * cfg.n_sparse // 2
    top_in = cfg.embed_dim + n_pairs
    return {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim), cfg.dtype)
            * cfg.embed_dim ** -0.5,
        "bot": _mlp_init(k2, list(cfg.bot_mlp), cfg.dtype),
        "top": _mlp_init(k3, [top_in] + list(cfg.top_mlp_hidden), cfg.dtype),
    }


def dlrm_forward(params: dict, dense: jax.Array, sparse_idx: jax.Array,
                 cfg: DLRMConfig, use_kernel: bool = False) -> jax.Array:
    """dense: (B, 13); sparse_idx: (B, 26, L). Returns (B,) logits."""
    z0 = _mlp(params["bot"], dense.astype(cfg.dtype), final_act=True)  # (B, D)
    emb = field_pool(params["tables"], sparse_idx, use_kernel=use_kernel)
    emb = constrain(emb, "act_bfd")
    feats = jnp.concatenate([z0[:, None, :], emb], axis=1)   # (B, 27, D)
    # dot interaction: upper triangle of (27 x 27) gram
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = gram[:, iu, ju]                                   # (B, 351)
    top_in = jnp.concatenate([z0, inter], axis=1)
    return _mlp(params["top"], top_in)[:, 0]


def dlrm_user_tower(params: dict, dense: jax.Array, sparse_idx: jax.Array,
                    cfg: DLRMConfig) -> jax.Array:
    """Two-tower retrieval adaptation: pooled user repr in item-embedding space."""
    z0 = _mlp(params["bot"], dense.astype(cfg.dtype), final_act=True)
    emb = field_pool(params["tables"], sparse_idx)
    return z0 + emb.mean(axis=1)                              # (B, D)


# --------------------------------------------------------------- xDeepFM

@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab: int = 1_000_000
    cin_layers: tuple = (200, 200, 200)
    mlp: tuple = (400, 400)
    dtype: object = jnp.float32


def xdeepfm_init(key: jax.Array, cfg: XDeepFMConfig) -> dict:
    ks = jax.random.split(key, 5 + len(cfg.cin_layers))
    m, d = cfg.n_sparse, cfg.embed_dim
    p = {
        "tables": jax.random.normal(ks[0], (m, cfg.vocab, d), cfg.dtype) * d ** -0.5,
        "linear": jax.random.normal(ks[1], (m, cfg.vocab, 1), cfg.dtype) * 0.01,
        "dnn": _mlp_init(ks[2], [m * d] + list(cfg.mlp) + [1], cfg.dtype),
        "cin": [],
        "cin_out": None,
    }
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        p["cin"].append(jax.random.normal(
            ks[3 + i], (h, h_prev * m), cfg.dtype) * (h_prev * m) ** -0.5)
        h_prev = h
    p["cin_out"] = jax.random.normal(
        ks[-1], (sum(cfg.cin_layers), 1), cfg.dtype) * 0.1
    return p


def xdeepfm_forward(params: dict, sparse_idx: jax.Array, cfg: XDeepFMConfig,
                    use_kernel: bool = False) -> jax.Array:
    """sparse_idx: (B, 39, L). Returns (B,) logits (pre-sigmoid)."""
    x0 = field_pool(params["tables"], sparse_idx, use_kernel=use_kernel)  # (B,m,D)
    x0 = constrain(x0, "act_bfd")
    b, m, d = x0.shape
    # CIN
    xk = x0
    pooled = []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0).reshape(b, -1, d)  # (B, Hk*m, D)
        xk = jnp.einsum("hp,bpd->bhd", w, z)                        # (B, Hk+1, D)
        pooled.append(xk.sum(axis=-1))                              # (B, Hk+1)
    cin_logit = jnp.concatenate(pooled, axis=1) @ params["cin_out"]  # (B, 1)
    # DNN
    dnn_logit = _mlp(params["dnn"], x0.reshape(b, m * d))
    # linear (order-1)
    lin = field_pool(params["linear"], sparse_idx).sum(axis=(1, 2))
    return (cin_logit + dnn_logit)[:, 0] + lin


def xdeepfm_user_tower(params: dict, sparse_idx: jax.Array,
                       cfg: XDeepFMConfig) -> jax.Array:
    """Two-tower retrieval adaptation (mean field embedding)."""
    return field_pool(params["tables"], sparse_idx).mean(axis=1)


# ------------------------------------------- sequential models (shared)

@dataclasses.dataclass(frozen=True)
class SeqRecConfig:
    name: str = "sasrec"
    vocab: int = 1_000_000
    max_len: int = 50
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    causal: bool = True          # SASRec causal; BERT4Rec bidirectional
    d_ff_mult: int = 4
    dtype: object = jnp.float32


def seqrec_init(key: jax.Array, cfg: SeqRecConfig) -> dict:
    ks = jax.random.split(key, 2 + 5 * cfg.n_blocks)
    d = cfg.embed_dim
    p = {
        "item_emb": jax.random.normal(ks[0], (cfg.vocab, d), cfg.dtype) * d ** -0.5,
        "pos_emb": jax.random.normal(ks[1], (cfg.max_len, d), cfg.dtype) * 0.02,
        "blocks": [],
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    for i in range(cfg.n_blocks):
        k = ks[2 + 5 * i: 7 + 5 * i]
        p["blocks"].append({
            "wq": jax.random.normal(k[0], (d, d), cfg.dtype) * d ** -0.5,
            "wk": jax.random.normal(k[1], (d, d), cfg.dtype) * d ** -0.5,
            "wv": jax.random.normal(k[2], (d, d), cfg.dtype) * d ** -0.5,
            "wo": jax.random.normal(k[3], (d, d), cfg.dtype) * d ** -0.5,
            "ffn": _mlp_init(k[4], [d, cfg.d_ff_mult * d, d], cfg.dtype),
            "norm1": jnp.ones((d,), jnp.float32),
            "norm2": jnp.ones((d,), jnp.float32),
        })
    return p


def seqrec_encode(params: dict, items: jax.Array, cfg: SeqRecConfig) -> jax.Array:
    """items: (B, S) int32, -1 = pad. Returns (B, S, D) hidden states."""
    b, s = items.shape
    d, h = cfg.embed_dim, cfg.n_heads
    mask = items >= 0
    x = params["item_emb"][jnp.maximum(items, 0)] * mask[..., None]
    x = x + params["pos_emb"][None, :s]
    x = constrain(x, "act_bsd")
    for blk in params["blocks"]:
        xn = cm.rms_norm(x, blk["norm1"])
        q = (xn @ blk["wq"]).reshape(b, s, h, d // h)
        k = (xn @ blk["wk"]).reshape(b, s, h, d // h)
        v = (xn @ blk["wv"]).reshape(b, s, h, d // h)
        o = cm.blockwise_attention(q, k, v, causal=cfg.causal,
                                   q_chunk=min(256, s), kv_chunk=min(256, s))
        x = x + o.reshape(b, s, d) @ blk["wo"]
        xn = cm.rms_norm(x, blk["norm2"])
        x = x + _mlp(blk["ffn"], xn)
    x = cm.rms_norm(x, params["final_norm"])
    return x * mask[..., None]


def seqrec_session_repr(params: dict, items: jax.Array, cfg: SeqRecConfig) -> jax.Array:
    """Last valid position's hidden state: the retrieval query vector."""
    hidden = seqrec_encode(params, items, cfg)
    lengths = jnp.maximum((items >= 0).sum(axis=1) - 1, 0)
    return jnp.take_along_axis(hidden, lengths[:, None, None], axis=1)[:, 0]


def seqrec_score_candidates(params: dict, session: jax.Array,
                            cand_ids: Optional[jax.Array] = None) -> jax.Array:
    """MIPS over item embeddings — the paper's metric-index scan.
    session: (B, D); cand_ids: (C,) or None for the full vocab."""
    table = params["item_emb"]
    if cand_ids is not None:
        table = table[cand_ids]
    return session @ table.T


def seqrec_bce_loss(params: dict, items: jax.Array, pos: jax.Array,
                    neg: jax.Array, cfg: SeqRecConfig) -> jax.Array:
    """SASRec-style BCE: one positive + one sampled negative per position.
    items/pos/neg: (B, S) (-1 pads)."""
    hidden = seqrec_encode(params, items, cfg)
    valid = pos >= 0
    e_pos = params["item_emb"][jnp.maximum(pos, 0)]
    e_neg = params["item_emb"][jnp.maximum(neg, 0)]
    s_pos = jnp.sum(hidden * e_pos, axis=-1)
    s_neg = jnp.sum(hidden * e_neg, axis=-1)
    loss = -(jax.nn.log_sigmoid(s_pos) + jax.nn.log_sigmoid(-s_neg))
    return (loss * valid).sum() / jnp.maximum(valid.sum(), 1)
