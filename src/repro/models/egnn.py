"""E(n)-Equivariant GNN (Satorras et al., arXiv:2102.09844).

Message passing over an explicit edge list via ``jax.ops.segment_sum`` —
JAX's sparse support is BCOO-only, so scatter/segment ops over an
edge-index ARE the SpMM substrate here (kernel regime: gather -> MLP ->
scatter).  Equivariance: coordinates are updated only along relative
difference vectors scaled by a scalar MLP of the invariant message.

Batched small graphs (``molecule`` shape) are flattened into one disjoint
graph with offset edge indices; ``graph_ids`` drives the readout.
Large graphs shard the *edge* arrays across devices; ``segment_sum``
partials then combine with a psum inserted by SPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat_in: int = 16
    d_edge: int = 0
    coords_dim: int = 3
    n_classes: int = 8
    readout: str = "node"      # "node" | "graph"
    residual: bool = True
    dtype: object = jnp.float32


def _mlp_init(key, sizes, dtype):
    ks = jax.random.split(key, len(sizes) - 1)
    return [{"w": jax.random.normal(k, (a, b), dtype) * a ** -0.5,
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def _mlp(layers, x, act=jax.nn.silu, last_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or last_act:
            x = act(x)
    return x


def init_params(key: jax.Array, cfg: EGNNConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    d_msg_in = 2 * d + 1 + cfg.d_edge
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append({
            "phi_e": _mlp_init(k1, [d_msg_in, d, d], cfg.dtype),
            "phi_x": _mlp_init(k2, [d, d, 1], cfg.dtype),
            "phi_h": _mlp_init(k3, [2 * d, d, d], cfg.dtype),
        })
    return {
        "encoder": _mlp_init(keys[-3], [cfg.d_feat_in, d], cfg.dtype),
        "layers": layers,
        "head": _mlp_init(keys[-2], [d, d, cfg.n_classes], cfg.dtype),
    }


def egnn_layer(p: dict, h: jax.Array, x: jax.Array, edge_index: jax.Array,
               edge_attr: Optional[jax.Array], n_nodes: int,
               residual: bool = True):
    """h: (N, d); x: (N, 3); edge_index: (2, E) [src, dst] (dst aggregates).
    Padded edges use index n_nodes-? -> we use src=dst=0 with zero edge
    weight via an explicit ``edge_mask`` folded into edge_attr? Padding
    convention: edges with src < 0 are masked."""
    src, dst = edge_index[0], edge_index[1]
    mask = (src >= 0)
    s = jnp.where(mask, src, 0)
    t = jnp.where(mask, dst, 0)

    dx = x[s] - x[t]                                       # (E, 3)
    dist2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
    feats = [h[s], h[t], dist2]
    if edge_attr is not None:
        feats.append(edge_attr)
    m = _mlp(p["phi_e"], jnp.concatenate(feats, axis=-1), last_act=True)
    m = m * mask[:, None]
    m = constrain(m, "edges")

    # coordinate update (equivariant): x_t += C * sum_j dx_ij * phi_x(m_ij)
    coef = _mlp(p["phi_x"], m)                              # (E, 1)
    coef = jnp.clip(coef, -100.0, 100.0) * mask[:, None]
    deg = jax.ops.segment_sum(mask.astype(x.dtype), t, n_nodes)
    x_agg = jax.ops.segment_sum(dx * coef, t, n_nodes)
    x_new = x + x_agg / jnp.maximum(deg, 1.0)[:, None]

    # feature update
    m_agg = jax.ops.segment_sum(m, t, n_nodes)
    m_agg = constrain(m_agg, "nodes")
    h_new = _mlp(p["phi_h"], jnp.concatenate([h, m_agg], axis=-1))
    if residual:
        h_new = h + h_new
    return h_new, x_new


def forward(params: dict, node_feat: jax.Array, coords: jax.Array,
            edge_index: jax.Array, cfg: EGNNConfig,
            edge_attr: Optional[jax.Array] = None,
            graph_ids: Optional[jax.Array] = None,
            n_graphs: Optional[int] = None):
    """Returns (logits, coords_out). logits: (N, C) node-level or (G, C)."""
    n = node_feat.shape[0]
    h = _mlp(params["encoder"], node_feat.astype(cfg.dtype))
    h = constrain(h, "nodes")
    x = coords.astype(cfg.dtype)
    layer = jax.checkpoint(
        lambda p, h, x: egnn_layer(p, h, x, edge_index, edge_attr, n,
                                   cfg.residual))
    for p in params["layers"]:
        h, x = layer(p, h, x)
        h = constrain(h, "nodes")
    if cfg.readout == "graph":
        assert graph_ids is not None and n_graphs is not None
        pooled = jax.ops.segment_sum(h, graph_ids, n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((n,), h.dtype), graph_ids, n_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)[:, None]
    logits = _mlp(params["head"], h)
    return logits, x
