"""Shared model building blocks: norms, RoPE, blockwise (flash-style) attention.

Attention is implemented as a pure-JAX *blockwise online-softmax* scan over
KV (and optionally Q) chunks, so peak memory is O(B*H*q_chunk*kv_chunk)
instead of O(B*H*S^2) — the same IO decomposition FlashAttention makes,
expressed at the XLA level (TPU target; a Pallas attention kernel would slot
in behind the same signature).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale) if zero_centered else scale
    return (y * w).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ------------------------------------------------------------------ RoPE
def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
               rotary_frac: float = 1.0, interleaved: bool = False) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32. Rotates the first
    rotary_frac*Dh dims (chatglm-style partial rotary when frac=0.5)."""
    dh = x.shape[-1]
    rot = int(dh * rotary_frac)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = rope_frequencies(rot, theta)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                     # (..., S, 1, rot/2)
    sin = sin[..., None, :]
    if interleaved:
        x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape)
    else:
        half = rot // 2
        x1, x2 = x_rot[..., :half], x_rot[..., half:]
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ------------------------------------------------- blockwise attention
def _mask_block(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window) -> jax.Array:
    """(q_chunk, k_chunk) bool mask: True = attend.

    ``window`` may be None (static: unlimited), a python int, or a traced
    int32 scalar where <= 0 means unlimited (lets a scanned per-layer window
    schedule drive local/global alternation, as in gemma2)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        in_win = q_pos[:, None] - k_pos[None, :] < w
        m &= jnp.logical_or(w <= 0, in_win)
    return m


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        q_chunk: int = 1024, kv_chunk: int = 1024,
                        logit_cap: Optional[float] = None,
                        scale: Optional[float] = None,
                        unroll: bool = False) -> jax.Array:
    """q/k: (B, Sq|Sk, H|KV, Dh); v: (B, Sk, KV, Dv) with H % KV == 0 (GQA).
    Dv may differ from Dh (MLA value heads).

    Online-softmax over KV chunks nested in a scan over Q chunks; fp32
    accumulators; memory O(B*H*q_chunk*kv_chunk).

    Flat-head layout: scores are (B, H, qc, kc) with H = all query heads, so
    the "attn_scores" sharding rule can put H on the model axis whenever
    n_heads divides it (true for 4/5 assigned LM archs) even when KV heads
    alone would not divide (GQA with KV < TP).  K/V chunks are broadcast to
    H inside the chunk loop — a (kc, H, Dh)-sized transient, cheap relative
    to the score block it replaces.
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kv
    scale = scale if scale is not None else dh ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = sq // q_chunk, sk // kv_chunk

    qg = q.reshape(b, nq, q_chunk, h, dh)
    kc = k.reshape(b, nk, kv_chunk, kv, dh)
    vc = v.reshape(b, nk, kv_chunk, kv, dv)

    def q_step(_, qi):
        qblk, q_pos = qi                                  # (B, qc, H, Dh)

        def kv_step(carry, ki):
            m_prev, l_prev, o_prev = carry
            kblk, vblk, k_pos = ki                        # (B, kc, KV, D*)
            krep = jnp.repeat(kblk, g, axis=2)            # (B, kc, H, Dh)
            vrep = jnp.repeat(vblk, g, axis=2)
            s = jnp.einsum("bqhd,bphd->bhqp", qblk.astype(jnp.float32),
                           krep.astype(jnp.float32)) * scale
            s = softcap(s, logit_cap)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            s = constrain(s, "attn_scores")
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            o_new = o_prev * corr[..., None] + jnp.einsum(
                "bhqp,bphd->bhqd", p, vrep.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        shape = (b, h, q_chunk)
        init = (jnp.full(shape, NEG_INF, jnp.float32),
                jnp.zeros(shape, jnp.float32),
                jnp.zeros(shape + (dv,), jnp.float32))
        k_positions = jnp.arange(sk).reshape(nk, kv_chunk)
        # checkpoint each kv step: backward recomputes the (B,H,qc,kc) score
        # block instead of saving it per step — the FlashAttention backward
        # expressed at XLA level (saved-residual profile goes from
        # O(nq*nk*qc*kc) to O(carries)).
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False), init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             k_positions), unroll=nk if unroll else 1)
        o = o / jnp.maximum(l[..., None], 1e-30)
        return None, o.transpose(0, 2, 1, 3)              # (B, qc, H, Dv)

    q_positions = jnp.arange(sq).reshape(nq, q_chunk)
    _, out = jax.lax.scan(q_step, None,
                          (qg.transpose(1, 0, 2, 3, 4), q_positions),
                          unroll=nq if unroll else 1)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, window: Optional[int] = None,
                     logit_cap: Optional[float] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token attention against a (B, Smax, KV, Dh) cache.

    cur_len: scalar/array — number of valid cache entries (new token already
    written at cur_len-1). O(S) reads; softmax reductions over a sharded
    S axis lower to all-reduces (flash-decoding-style merge done by SPMD).
    """
    b, one, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(b, kv, g, dh)
    s = jnp.einsum("bkgd,bpkd->bkgp", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = softcap(s, logit_cap)
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < cur_len.reshape(-1, 1)
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        in_win = pos[None, :] >= cur_len.reshape(-1, 1) - w
        valid &= jnp.logical_or(w <= 0, in_win)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    # softmax over a (possibly seq-sharded) cache axis: SPMD lowers the max
    # and sum to all-reduces == flash-decoding partial-softmax merge.
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgp,bpkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, dh).astype(q.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean token CE in fp32; optional z-loss. labels < 0 are masked.

    The label log-prob is extracted with a masked reduction instead of
    ``take_along_axis`` — gathering along a vocab-sharded axis makes GSPMD
    all-gather the full (B, S, V) logits (measured: +100 GiB/device on the
    deepseek train cell); the mask-and-reduce keeps V sharded and lowers the
    reduction to a psum.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    hit = vocab_iota == jnp.maximum(labels, 0)[..., None]
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = labels >= 0
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
