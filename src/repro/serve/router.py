"""Serving router: straggler mitigation + degraded answers.

The back-end index is a set of shard handles (callables).  Production
posture for thousands of nodes:

  * **Batched scatter-gather**: concurrent session queries arrive as one
    stacked ``search`` (the paper batches 216 queries into FAISS for the
    same reason); admission batching itself lives in
    ``repro.serve.scheduler``.
  * **Hedging / straggler mitigation**: each shard call runs with a
    deadline; shards that miss it are retried once (hedge), and if the
    retry also misses, the router returns a *degraded* answer assembled
    from the shards that did respond — the merge of per-shard top-k is
    correct on the surviving subset.
  * **Cache as fault tolerance**: when the client holds a CACHE, a degraded
    or failed back-end turn can still be answered from cached embeddings —
    the paper's mechanism doubles as a resilience layer (tested).

This module is deliberately execution-agnostic (thread pool here; the same
logic fronts RPC stubs on a real cluster).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ShardAnswer:
    scores: np.ndarray     # (B, k)
    ids: np.ndarray        # (B, k)


@dataclasses.dataclass
class RouterStats:
    calls: int = 0
    hedges: int = 0
    failures: int = 0
    degraded: int = 0
    duplicates: int = 0    # hedge losers whose answers were discarded


def _discard(future: cf.Future) -> bool:
    """Drop a future we no longer want: cancel if not started, otherwise
    attach a consumer so its result/exception is drained, never merged.
    Returns True when the future was already running (a real duplicate
    in flight), False when it was cancelled before ever starting."""
    if future.cancel():
        return False
    future.add_done_callback(lambda f: f.exception())
    return True


class ShardedRouter:
    """shards: callables (queries, k) -> ShardAnswer, one per corpus shard.

    Shards may be plain host callables (RPC stubs, test lambdas) or
    device-resident handles — ``over_devices`` builds a router fronting
    ``repro.dist.retrieval.DeviceShard``s, one corpus slice per device.
    """

    def __init__(self, shards: Sequence[Callable], deadline_s: float = 1.0,
                 hedge_after_s: Optional[float] = None, max_workers: int = 16):
        self.shards = list(shards)
        self.deadline_s = deadline_s
        self.hedge_after_s = hedge_after_s or deadline_s / 2
        self.pool = cf.ThreadPoolExecutor(max_workers=max_workers)
        self.stats = RouterStats()

    @classmethod
    def over_devices(cls, docs, doc_ids=None, *, devices=None,
                     chunk: int = 4096, **kwargs) -> "ShardedRouter":
        """Router fronting device-sharded corpus slices (one per device)."""
        from repro.dist.retrieval import make_device_shards
        return cls(make_device_shards(docs, doc_ids, devices=devices,
                                      chunk=chunk), **kwargs)

    def search(self, queries: np.ndarray, k: int) -> tuple[ShardAnswer, bool]:
        """Scatter-gather with hedging. Returns (merged answer, degraded?).

        A hedged retry and its original can both complete; the first answer
        per shard wins and every sibling in flight for that shard is
        explicitly discarded (``cancel()`` alone is a no-op once a future is
        running), so a shard's answer is merged at most once and the loop
        never stalls waiting on a hedge loser.
        """
        self.stats.calls += 1
        answers: dict[int, ShardAnswer] = {}
        deadline = time.monotonic() + self.deadline_s
        hedge_at = time.monotonic() + self.hedge_after_s
        hedged: set[int] = set()
        pending: dict[cf.Future, int] = {
            self.pool.submit(s, queries, k): i
            for i, s in enumerate(self.shards)}
        while pending and time.monotonic() < deadline:
            done, _ = cf.wait(list(pending), timeout=0.005,
                              return_when=cf.FIRST_COMPLETED)
            for f in done:
                i = pending.pop(f, None)
                if i is None:          # sibling already discarded below
                    continue
                try:
                    result = f.result()
                except Exception:
                    self.stats.failures += 1
                    continue
                answers[i] = result
                # drop the hedge sibling (winner merged, loser drained);
                # only a loser that actually ran counts as duplicate work
                for f2, i2 in list(pending.items()):
                    if i2 == i:
                        del pending[f2]
                        self.stats.duplicates += _discard(f2)
            # hedge slow shards once
            if time.monotonic() >= hedge_at:
                for f, i in list(pending.items()):
                    if i not in hedged:
                        hedged.add(i)
                        self.stats.hedges += 1
                        pending[self.pool.submit(self.shards[i], queries, k)] = i
                hedge_at = float("inf")
        for f in pending:
            _discard(f)
        degraded = len(answers) < len(self.shards)
        if degraded:
            self.stats.degraded += 1
        if not answers:
            raise TimeoutError("all index shards failed or timed out")
        return self._merge(list(answers.values()), k), degraded

    @staticmethod
    def _merge(parts: list[ShardAnswer], k: int) -> ShardAnswer:
        """Merge per-shard top-k, always returning exactly ``k`` columns.

        Surviving shards may hold fewer than k candidates in total (tiny
        shards, degraded subsets); short rows are padded with explicit
        sentinels (score -inf, id -1) so consumers can detect them instead
        of misreading the last column as the true k-th neighbour.
        """
        scores = np.concatenate([p.scores for p in parts], axis=1)
        ids = np.concatenate([p.ids for p in parts], axis=1)
        if scores.shape[1] < k:
            pad = k - scores.shape[1]
            scores = np.pad(scores, ((0, 0), (0, pad)),
                            constant_values=-np.inf)
            ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        return ShardAnswer(np.take_along_axis(scores, order, axis=1),
                           np.take_along_axis(ids, order, axis=1))
