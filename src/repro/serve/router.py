"""Serving router: straggler mitigation, circuit breakers, degraded answers.

The back-end index is a set of shard handles (callables).  Production
posture for thousands of nodes:

  * **Batched scatter-gather**: concurrent session queries arrive as one
    stacked ``search`` (the paper batches 216 queries into FAISS for the
    same reason); admission batching itself lives in
    ``repro.serve.scheduler``.
  * **Hedging / straggler mitigation**: each shard call runs with a
    deadline; shards that miss it are retried once (hedge), and if the
    retry also misses, the router returns a *degraded* answer assembled
    from the shards that did respond — the merge of per-shard top-k is
    correct on the surviving subset.
  * **Circuit breakers**: each shard carries a closed / open / half-open
    ``CircuitBreaker`` over a sliding failure-rate window.  An open
    shard is skipped *immediately* (no submit, no deadline wait) and the
    merge marked degraded; after ``breaker_cooldown_s`` the breaker goes
    half-open and admits exactly one probe call — success re-closes it,
    failure re-opens.  A flapping shard therefore costs one probe per
    cooldown instead of a deadline per search.  When EVERY breaker is
    open the router is ``backend_open`` and ``search`` fails fast (the
    engine load-sheds the wave instead of waiting out the deadline).
  * **Bounded retry**: a failed or rejected shard call is retried up to
    ``max_retries`` times with exponential backoff and deterministic
    jitter, always inside the remaining deadline budget.
  * **Answer validation**: a shard answer is checked (shape, dtype,
    finite scores, id bounds) *before* it can reach ``_merge`` — a NaN
    score column would otherwise silently corrupt the ``argsort`` rank
    order.  Rejected answers count as shard failures.
  * **Cache as fault tolerance**: when the client holds a CACHE, a degraded
    or failed back-end turn can still be answered from cached embeddings —
    the paper's mechanism doubles as a resilience layer (tested).

This module is deliberately execution-agnostic (thread pool here; the same
logic fronts RPC stubs on a real cluster).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = ["ShardAnswer", "RouterStats", "CircuitBreaker", "ShardedRouter",
           "AnswerValidationError", "validate_answer"]


@dataclasses.dataclass
class ShardAnswer:
    scores: np.ndarray     # (B, k)
    ids: np.ndarray        # (B, k)


class AnswerValidationError(ValueError):
    """A shard answer failed validation (malformed, NaN, out-of-range)."""


def validate_answer(ans, n_queries: int, k: int,
                    n_docs: Optional[int] = None) -> None:
    """Reject a malformed shard answer before it can poison ``_merge``.

    Checks: ``scores``/``ids`` are 2-D with matching shapes, one row per
    query and at most ``k`` columns (short answers from tiny shards are
    legal — the merge sentinel-pads them); ``ids`` are integral, ``>= -1``
    and (when the corpus size is known) ``< n_docs``; ``scores`` carry no
    NaN and no ``+inf``, and ``-inf`` only on ``id == -1`` sentinel slots.
    Raises ``AnswerValidationError``; never mutates the answer.
    """
    scores = getattr(ans, "scores", None)
    ids = getattr(ans, "ids", None)
    if scores is None or ids is None:
        raise AnswerValidationError("answer missing scores/ids")
    scores, ids = np.asarray(scores), np.asarray(ids)
    if scores.ndim != 2 or scores.shape != ids.shape:
        raise AnswerValidationError(
            f"bad answer shape: scores {scores.shape} ids {ids.shape}")
    if scores.shape[0] != n_queries or not (1 <= scores.shape[1] <= k):
        raise AnswerValidationError(
            f"answer shape {scores.shape} vs ({n_queries}, <= {k}) owed")
    if not np.issubdtype(ids.dtype, np.integer):
        raise AnswerValidationError(f"non-integral ids ({ids.dtype})")
    if (ids < -1).any() or (n_docs is not None and (ids >= n_docs).any()):
        raise AnswerValidationError("doc ids out of range")
    if np.isnan(scores).any() or (scores == np.inf).any():
        raise AnswerValidationError("non-finite scores (NaN/+inf)")
    if np.logical_and(np.isneginf(scores), ids != -1).any():
        raise AnswerValidationError("-inf score on a non-sentinel id")


@dataclasses.dataclass
class RouterStats:
    """Router health counters.  All mutation goes through ``bump`` /
    ``shard_bump`` under one lock — concurrent ``search`` calls (the
    scheduler overlaps backend waves) would otherwise lose ``+=``
    updates.  ``per_shard`` holds one counter dict per shard:
    ``calls`` / ``failures`` / ``rejected`` / ``timeouts`` / ``retries``
    / ``breaker_skips``."""

    calls: int = 0
    hedges: int = 0
    failures: int = 0
    degraded: int = 0
    duplicates: int = 0    # hedge losers whose answers were discarded
    retries: int = 0       # backoff re-attempts inside one shard call
    rejected: int = 0      # shard answers refused by validation
    timeouts: int = 0      # shard calls written off at the deadline
    breaker_skips: int = 0  # shard calls skipped: breaker open
    breaker_opens: int = 0
    breaker_closes: int = 0
    shed: int = 0          # whole searches refused: every breaker open
    per_shard: list = dataclasses.field(default_factory=list)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def bump(self, name: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + n)

    def shard_bump(self, shard: int, name: str, n: int = 1) -> None:
        with self._lock:
            self.per_shard[shard][name] += n


class CircuitBreaker:
    """Per-shard closed -> open -> half-open breaker.

    Failure accounting is a sliding window of the last ``window`` call
    outcomes; once at least ``min_calls`` outcomes are in the window and
    the failure fraction reaches ``fail_rate``, the breaker OPENS:
    ``allow()`` refuses calls until ``cooldown_s`` has elapsed, then the
    breaker goes HALF-OPEN and admits exactly one probe call — a
    successful probe resets the window and re-closes, a failed one
    re-opens and re-arms the cooldown.  ``clock`` is injectable for
    deterministic tests; ``on_transition(old, new)`` (kept cheap — it
    runs under the breaker lock) feeds stats/telemetry.
    """

    def __init__(self, window: int = 16, fail_rate: float = 0.5,
                 min_calls: int = 4, cooldown_s: float = 0.5,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Optional[Callable] = None):
        if not (0.0 < fail_rate <= 1.0):
            raise ValueError("fail_rate must be in (0, 1]")
        self.window, self.fail_rate = window, fail_rate
        self.min_calls, self.cooldown_s = min_calls, cooldown_s
        self.on_transition = on_transition
        self._clock = clock
        self._lock = threading.Lock()
        self._outcomes: list[bool] = []
        self._opened_at = 0.0
        self._probe_out = False
        self.state = "closed"
        self.opens = 0
        self.closes = 0

    def _transition(self, new: str) -> None:
        old, self.state = self.state, new
        if new == "open":
            self.opens += 1
            self._opened_at = self._clock()
            self._outcomes.clear()
        elif new == "closed":
            self.closes += 1
            self._outcomes.clear()
        if self.on_transition is not None:
            self.on_transition(old, new)

    def allow(self) -> bool:
        """May a call go out now?  (Mutates: grants the half-open probe.)"""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                if self._clock() - self._opened_at < self.cooldown_s:
                    return False
                self._transition("half_open")
                self._probe_out = True
                return True
            if self._probe_out:     # half-open: one probe in flight
                return False
            self._probe_out = True
            return True

    def peek(self) -> bool:
        """Non-mutating: would ``allow()`` grant a call right now?"""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                return self._clock() - self._opened_at >= self.cooldown_s
            return not self._probe_out

    def record(self, ok: bool) -> None:
        """Fold one call outcome in (success, failure, or timeout)."""
        with self._lock:
            if self.state == "half_open":
                self._probe_out = False
                self._transition("closed" if ok else "open")
                return
            if self.state == "open":
                return              # late result of a pre-open call
            self._outcomes.append(bool(ok))
            if len(self._outcomes) > self.window:
                del self._outcomes[0]
            n = len(self._outcomes)
            if n >= self.min_calls and \
                    (n - sum(self._outcomes)) / n >= self.fail_rate:
                self._transition("open")


def _discard(future: cf.Future) -> bool:
    """Drop a future we no longer want: cancel if not started, otherwise
    attach a consumer so its result/exception is drained, never merged.
    Returns True when the future was already running (a real duplicate
    in flight), False when it was cancelled before ever starting."""
    if future.cancel():
        return False
    future.add_done_callback(lambda f: f.exception())
    return True


def _jitter(shard: int, call: int, attempt: int) -> float:
    """Deterministic backoff jitter in [0, 1): hashed from the call
    coordinates, so retry timing is reproducible without shared RNG
    state across router threads."""
    h = (shard * 2654435761 + call * 40503 + attempt * 69069) & 0xFFFFFFFF
    return (h % 1000) / 1000.0


class ShardedRouter:
    """shards: callables (queries, k) -> ShardAnswer, one per corpus shard.

    Shards may be plain host callables (RPC stubs, test lambdas) or
    device-resident handles — ``over_devices`` builds a router fronting
    ``repro.dist.retrieval.DeviceShard``s, one corpus slice per device.

    Owns a thread pool: ``close()`` it (or use the router as a context
    manager) so worker threads don't leak across benchmark runs/tests.
    """

    def __init__(self, shards: Sequence[Callable], deadline_s: float = 1.0,
                 hedge_after_s: Optional[float] = None, max_workers: int = 16,
                 max_retries: int = 1, backoff_base_s: float = 0.01,
                 n_docs: Optional[int] = None,
                 breaker_window: int = 16, breaker_fail_rate: float = 0.5,
                 breaker_min_calls: int = 4, breaker_cooldown_s: float = 0.5,
                 telemetry=None):
        self.shards = list(shards)
        self.deadline_s = deadline_s
        self.hedge_after_s = hedge_after_s or deadline_s / 2
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.n_docs = n_docs
        self.telemetry = telemetry
        self.pool = cf.ThreadPoolExecutor(max_workers=max_workers)
        self.stats = RouterStats(per_shard=[
            {"calls": 0, "failures": 0, "rejected": 0, "timeouts": 0,
             "retries": 0, "breaker_skips": 0}
            for _ in self.shards])
        self.breakers = [
            CircuitBreaker(window=breaker_window,
                           fail_rate=breaker_fail_rate,
                           min_calls=breaker_min_calls,
                           cooldown_s=breaker_cooldown_s,
                           on_transition=self._transition_cb(i))
            for i in range(len(self.shards))]

    @classmethod
    def over_devices(cls, docs, doc_ids=None, *, devices=None,
                     chunk: int = 4096, **kwargs) -> "ShardedRouter":
        """Router fronting device-sharded corpus slices (one per device)."""
        from repro.dist.retrieval import make_device_shards
        return cls(make_device_shards(docs, doc_ids, devices=devices,
                                      chunk=chunk), **kwargs)

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Shut the scatter-gather pool down (idempotent).  In-flight
        calls are cancelled where possible; further ``search``es raise."""
        self.pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self) -> "ShardedRouter":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------ health
    def _transition_cb(self, shard: int) -> Callable:
        def cb(old: str, new: str) -> None:
            if new == "open":
                self.stats.bump("breaker_opens")
            elif new == "closed":
                self.stats.bump("breaker_closes")
            if self.telemetry is not None:
                self.telemetry.record_breaker(shard, old, new)
        return cb

    @property
    def backend_open(self) -> bool:
        """True when no shard would currently accept a call — the whole
        back end is fenced off and callers should load-shed instead of
        paying the deadline."""
        return not any(b.peek() for b in self.breakers)

    def shard_health(self) -> list:
        """Per-shard snapshot: breaker state + lifetime counters."""
        with self.stats._lock:
            counters = [dict(d) for d in self.stats.per_shard]
        return [{"state": b.state, "opens": b.opens, "closes": b.closes,
                 **c} for b, c in zip(self.breakers, counters)]

    # ------------------------------------------------------------ search
    def _call(self, i: int, queries: np.ndarray, k: int, call_id: int,
              deadline: float) -> ShardAnswer:
        """One shard call with validation + bounded backoff retry, run on
        a pool thread.  Records every attempt's outcome into the shard's
        breaker; raises only once the retry budget (or the remaining
        deadline) is exhausted."""
        attempt = 0
        while True:
            try:
                ans = self.shards[i](queries, k)
                validate_answer(ans, len(queries), k, self.n_docs)
                self.breakers[i].record(True)
                self.stats.shard_bump(i, "calls")
                return ans
            except AnswerValidationError:
                self.breakers[i].record(False)
                self.stats.bump("rejected")
                self.stats.shard_bump(i, "rejected")
                self.stats.shard_bump(i, "calls")
                if self.telemetry is not None:
                    self.telemetry.record_fault("rejected_answers")
            except Exception:
                self.breakers[i].record(False)
                self.stats.shard_bump(i, "failures")
                self.stats.shard_bump(i, "calls")
            attempt += 1
            delay = self.backoff_base_s * (2.0 ** (attempt - 1))
            delay *= 1.0 + _jitter(i, call_id, attempt)
            if attempt > self.max_retries or \
                    time.monotonic() + delay >= deadline:
                raise TimeoutError(f"shard {i} failed (attempt {attempt})")
            self.stats.bump("retries")
            self.stats.shard_bump(i, "retries")
            time.sleep(delay)

    def search(self, queries: np.ndarray, k: int) -> tuple[ShardAnswer, bool]:
        """Scatter-gather with breakers + hedging.  Returns (merged
        answer, degraded?).

        Open-breaker shards are skipped up front (their absence alone
        marks the merge degraded); a half-open shard gets its single
        probe call.  The gather loop wakes on completions, the hedge
        point, or the deadline — never a fixed busy-poll.  A hedged
        retry and its original can both complete; the first answer per
        shard wins and every sibling in flight for that shard is
        explicitly discarded (``cancel()`` alone is a no-op once a
        future is running), so a shard's answer is merged at most once
        and the loop never stalls waiting on a hedge loser.
        """
        self.stats.bump("calls")
        call_id = self.stats.calls
        answers: dict[int, ShardAnswer] = {}
        deadline = time.monotonic() + self.deadline_s
        hedge_at = time.monotonic() + self.hedge_after_s
        hedged: set[int] = set()
        pending: dict[cf.Future, int] = {}
        for i, _ in enumerate(self.shards):
            if self.breakers[i].allow():
                pending[self.pool.submit(
                    self._call, i, queries, k, call_id, deadline)] = i
            else:
                self.stats.bump("breaker_skips")
                self.stats.shard_bump(i, "breaker_skips")
        if not pending:
            self.stats.bump("shed")
            self.stats.bump("degraded")
            raise TimeoutError(
                "back end fenced: every shard's circuit breaker is open")
        while pending and (now := time.monotonic()) < deadline:
            # wake on a completion, the hedge point, or the deadline —
            # whichever is first (no fixed-interval busy-poll)
            wait_s = max(min(hedge_at, deadline) - now, 0.0)
            done, _ = cf.wait(list(pending), timeout=wait_s,
                              return_when=cf.FIRST_COMPLETED)
            for f in done:
                i = pending.pop(f, None)
                if i is None:          # sibling already discarded below
                    continue
                try:
                    result = f.result()
                except Exception:
                    self.stats.bump("failures")
                    continue
                answers[i] = result
                # drop the hedge sibling (winner merged, loser drained);
                # only a loser that actually ran counts as duplicate work
                for f2, i2 in list(pending.items()):
                    if i2 == i:
                        del pending[f2]
                        if _discard(f2):
                            self.stats.bump("duplicates")
            # hedge slow shards once (closed breakers only: a half-open
            # shard's single probe must stay single)
            if time.monotonic() >= hedge_at:
                for f, i in list(pending.items()):
                    if i not in hedged and self.breakers[i].state == "closed":
                        hedged.add(i)
                        self.stats.bump("hedges")
                        pending[self.pool.submit(
                            self._call, i, queries, k, call_id, deadline)] = i
                hedge_at = float("inf")
        # shards still pending at the deadline are written off as
        # timeouts — the breaker hears about them (a shard that never
        # answers must be able to trip its breaker too)
        for i in set(pending.values()):
            self.stats.bump("timeouts")
            self.stats.shard_bump(i, "timeouts")
            self.breakers[i].record(False)
        for f in pending:
            _discard(f)
        degraded = len(answers) < len(self.shards)
        if degraded:
            self.stats.bump("degraded")
        if not answers:
            raise TimeoutError("all index shards failed or timed out")
        return self._merge(list(answers.values()), k), degraded

    @staticmethod
    def _merge(parts: list[ShardAnswer], k: int) -> ShardAnswer:
        """Merge per-shard top-k, always returning exactly ``k`` columns.

        Surviving shards may hold fewer than k candidates in total (tiny
        shards, degraded subsets); short rows are padded with explicit
        sentinels (score -inf, id -1) so consumers can detect them instead
        of misreading the last column as the true k-th neighbour.  Inputs
        are pre-validated (``validate_answer``), so the sort never ranks
        on NaN.
        """
        scores = np.concatenate([p.scores for p in parts], axis=1)
        ids = np.concatenate([p.ids for p in parts], axis=1)
        if scores.shape[1] < k:
            pad = k - scores.shape[1]
            scores = np.pad(scores, ((0, 0), (0, pad)),
                            constant_values=-np.inf)
            ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        return ShardAnswer(np.take_along_axis(scores, order, axis=1),
                           np.take_along_axis(ids, order, axis=1))
