"""Serving tier: hedging + circuit-breaking shard router, single-session
engine, the session-batched multi-session engine, the continuous-batching
scheduler + telemetry front door, and the deterministic fault injector
behind the chaos gate."""

from repro.serve.engine import ConversationalEngine, EngineTurn
from repro.serve.faults import (FaultError, FaultPlan, FaultSpec,
                                FaultyShard, chaos_plan)
from repro.serve.router import (AnswerValidationError, CircuitBreaker,
                                RouterStats, ShardAnswer, ShardedRouter,
                                validate_answer)
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.session import BatchedEngine, SessionManager
from repro.serve.telemetry import ServeTelemetry, TurnSpans

__all__ = ["ConversationalEngine", "EngineTurn",
           "ShardAnswer", "ShardedRouter", "RouterStats", "CircuitBreaker",
           "AnswerValidationError", "validate_answer",
           "FaultError", "FaultPlan", "FaultSpec", "FaultyShard",
           "chaos_plan",
           "BatchedEngine", "SessionManager",
           "ContinuousScheduler", "ServeTelemetry", "TurnSpans"]
