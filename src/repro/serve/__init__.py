"""Serving tier: hedging shard router, single-session engine, and the
session-batched multi-session engine + scheduler."""

from repro.serve.engine import ConversationalEngine, EngineTurn
from repro.serve.router import MicroBatcher, ShardAnswer, ShardedRouter
from repro.serve.session import BatchedEngine, SessionManager

__all__ = ["ConversationalEngine", "EngineTurn", "MicroBatcher",
           "ShardAnswer", "ShardedRouter", "BatchedEngine", "SessionManager"]
