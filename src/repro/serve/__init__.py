"""Serving tier: hedging shard router, single-session engine, the
session-batched multi-session engine, and the continuous-batching
scheduler + telemetry front door."""

from repro.serve.engine import ConversationalEngine, EngineTurn
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.session import BatchedEngine, SessionManager
from repro.serve.telemetry import ServeTelemetry, TurnSpans

__all__ = ["ConversationalEngine", "EngineTurn",
           "ShardAnswer", "ShardedRouter", "BatchedEngine", "SessionManager",
           "ContinuousScheduler", "ServeTelemetry", "TurnSpans"]
