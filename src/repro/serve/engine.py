"""End-to-end conversational search engine (Fig. 2 of the paper).

Client side: query encoder (any LM backbone -> pooled, projected embedding)
+ per-session MetricCache.  Server side: sharded metric index behind the
straggler-hedging router.  ``answer()`` implements Algorithm 1 with one
resilience extension: if the back-end comes back *degraded* (some shards
timed out), the turn still completes — and if the back-end fails entirely,
a non-empty cache serves a best-effort answer (cache as fault tolerance).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cache import CacheConfig, MetricCache
from repro.core.embedding import distance_from_scores, transform_queries
from repro.serve.router import ShardedRouter


def make_lm_query_encoder(params, cfg, proj: jax.Array):
    """Mean-pooled final hidden states -> R^l -> Eq.1 transform.

    proj: (d_model, l) projection to the retrieval space (in a full system
    this is fine-tuned contrastively; here it is part of the encoder
    params)."""
    from repro.models import transformer as tf

    @jax.jit
    def encode(tokens: jax.Array) -> jax.Array:
        _, _, hidden, _ = tf.forward(params, tokens, cfg, remat="none")
        mask = (tokens >= 0)[..., None]
        pooled = (hidden * mask).sum(1) / jnp.maximum(mask.sum(1), 1)
        return transform_queries(pooled @ proj)

    return encode


@dataclasses.dataclass
class EngineTurn:
    ids: np.ndarray
    scores: np.ndarray
    hit: bool
    degraded: bool
    latency_s: float
    # which tier of the cache hierarchy served the turn: "l1" (session
    # cache; also the single-session engine's only hit tier), "l2" (shared
    # cross-session cache), "l2_reuse" (semantic result-set reuse from the
    # shared tier's memo), or "backend" (full retrieval).  ``hit`` stays
    # the paper's notion — True iff no back-end query was needed.
    tier: str = "l1"
    # latency_s is admission-to-resolution; queue_wait_s breaks out the
    # time between admission and the wave actually starting (0 for the
    # single-session engine, which has no queue).  ``spans`` carries the
    # full repro.serve.telemetry.TurnSpans decomposition when the turn
    # came through the batched pipeline.
    queue_wait_s: float = 0.0
    spans: Optional[object] = None
    # how many of this turn's returned docs were brought into the session
    # cache by cluster prefetch (repro.core.cluster) rather than by a
    # back-end answer — the per-turn warm-hit signal the prefetch Pareto
    # sweep aggregates.  Always 0 without a cluster index attached.
    prefetch_hits: int = 0


def radius_and_docs(scores: np.ndarray, ids: np.ndarray,
                    doc_embeddings: np.ndarray):
    """r_a and insertable docs from one merged back-end row.

    The merge pads short rows (surviving shards held < k_c candidates) with
    (score -inf, id -1) sentinels: r_a is taken from the *last valid*
    column — the distance of the farthest doc actually retrieved, a
    conservative under-claim — never from a sentinel, whose -inf score
    would turn into an infinite radius.  Sentinel ids are clipped for the
    embedding lookup; ``insert`` drops ids < 0 so they are never cached.
    """
    n_valid = int((ids >= 0).sum())
    if n_valid == 0:
        raise TimeoutError("back-end answer holds no valid documents")
    radius = float(distance_from_scores(scores[n_valid - 1]))
    emb = jnp.asarray(doc_embeddings[np.maximum(ids, 0)])
    return radius, emb, jnp.asarray(ids)


class ConversationalEngine:
    """One engine instance serves one client session at a time (the paper's
    client model); the router/back-end is shared across engines."""

    def __init__(self, router: ShardedRouter, doc_embeddings: np.ndarray,
                 *, dim: int, k: int = 10, k_c: int = 1000,
                 epsilon: float = 0.04, capacity: Optional[int] = None,
                 encoder: Optional[Callable] = None,
                 dtype: Optional[str] = None):
        self.router = router
        self.doc_embeddings = doc_embeddings   # transformed, host-side lookup
        self.k, self.k_c, self.epsilon = k, k_c, epsilon
        self.encoder = encoder
        # dtype: the cache's embedding storage format (quant.DTYPES; None
        # follows the REPRO_CORPUS_DTYPE policy) — client memory shrinks
        # 2x / 4x at bf16 / int8 (paper RQ1.C)
        self.cache = MetricCache(CacheConfig(
            capacity=capacity or 16 * k_c, dim=dim, epsilon=epsilon,
            store_dtype=quant.resolve_dtype(dtype)))
        self.turns: list[EngineTurn] = []

    def start_session(self):
        self.cache.reset()
        self.turns = []

    def answer(self, query) -> EngineTurn:
        t0 = time.perf_counter()
        psi = self.encoder(query) if self.encoder else jnp.asarray(query)
        probe = self.cache.probe(psi)
        need_backend = self.cache.n_queries == 0 or not bool(probe.hit)
        degraded = False
        if need_backend:
            try:
                ans, degraded = self.router.search(
                    np.asarray(psi)[None], self.k_c)
                radius, emb, ids = radius_and_docs(
                    ans.scores[0], ans.ids[0], self.doc_embeddings)
                # A degraded merge is missing shards, so its k_c-th distance
                # is inflated: recording (psi, r_a) would over-claim coverage
                # and yield false hits on later turns.  Keep the docs, skip
                # the query record (record=False).
                self.cache.insert(psi, radius, emb, ids,
                                  record=not degraded)
            except TimeoutError:
                # total back-end failure: fall back to the cache if possible
                degraded = True
                if self.cache.n_docs == 0:
                    raise
        scores, dists, ids, _ = self.cache.query(psi, self.k)
        # a cache holding fewer than k docs pads with (id -1, score -inf)
        # sentinel slots; drop them so they never reach rankings or metrics
        ids, scores = np.asarray(ids), np.asarray(scores)
        real = ids >= 0
        turn = EngineTurn(ids=ids[real], scores=scores[real],
                          hit=not need_backend, degraded=degraded,
                          latency_s=time.perf_counter() - t0,
                          tier="l1" if not need_backend else "backend")
        self.turns.append(turn)
        return turn

    def hit_rate(self) -> float:
        if len(self.turns) <= 1:
            return float("nan")
        return float(np.mean([t.hit for t in self.turns[1:]]))
