"""Continuous batching: the slot-scheduled serving front door.

``ContinuousScheduler`` replaces the fixed-window ``MicroBatcher`` front
door (removed after its one-release deprecation; see the migration note
in docs/architecture.md).  The old front door held every arrival until a
batch filled or a wall-clock window expired, then ran the whole batch
synchronously — so a turn's latency was dominated by a queueing delay
nobody measured, and the engine sat idle while the window timer ran.  The
scheduler instead:

  * **admits continuously** — a dedicated worker forms the next wave from
    whatever is queued the moment the engine can take it (no window timer;
    an optional ``window_s`` hold survives only as serve_bench's
    fixed-window baseline);
  * **pipelines waves** — with an engine exposing the split wave contract
    (``probe_wave`` / ``backend_wave`` / ``fill_wave``,
    ``repro.serve.session.BatchedEngine``), the L1/L2 cache probe of wave
    *t+1* runs while wave *t*'s back-end search is in flight on a side
    thread.  All cache-state kernel launches stay on the worker thread, so
    waves are serialized where it matters and per-session results remain
    bit-identical to the sequential engine;
  * **sizes itself from telemetry** — an EWMA of the arrival rate times an
    EWMA of wave service time (x ``headroom``) sets the live wave bucket /
    active-slot limit, clamped to ``[min_wave, max_wave]`` and rounded to
    the engine's power-of-two jit buckets; an optional ``target_p99_s``
    backs the limit off when the measured turn p99 overshoots;
  * **stamps admission** — every ``submit`` carries an admission
    timestamp, so queue wait is part of each turn's measured latency
    (``EngineTurn.latency_s`` is admission-to-resolution);
  * **drains per slot** — ``drain_slot`` executes only the closing
    session's pending turns (bypassing any hold), leaving other sessions'
    queued turns to their own schedule instead of force-flushing the
    world.

Migration from ``MicroBatcher``: ``MicroBatcher(fn, max_batch, window_s)``
is ``ContinuousScheduler(fn=fn, max_wave=max_batch, window_s=window_s,
adaptive=False, overlap=False)``; serving code should go through
``SessionManager`` instead.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Callable, Optional

from repro.serve.telemetry import ServeTelemetry

__all__ = ["ContinuousScheduler"]


class _Item:
    """One admitted turn: payload + slot + waiter + admission stamp."""

    __slots__ = ("payload", "slot", "future", "admitted_at", "released")

    def __init__(self, payload, slot):
        self.payload = payload
        self.slot = slot
        self.future: cf.Future = cf.Future()
        self.admitted_at = time.perf_counter()
        # released: the item was queued when a wave fired (window mode
        # would have flushed it); it no longer waits on any window hold
        # even if it could not join that wave (same-slot defer)
        self.released = False


class _Inflight:
    """A begun wave: its probe state, waiters, and the back-end future."""

    __slots__ = ("ws", "items", "backend_future", "t_start")

    def __init__(self, ws, items, backend_future, t_start):
        self.ws = ws
        self.items = items
        self.backend_future = backend_future
        self.t_start = t_start


class ContinuousScheduler:
    """Slot-scheduled admission pipeline over a wave engine (or plain fn).

    Two execution modes share the admission queue and sizing policy:

    * **engine mode** (``engine=``): items are ``(slot, query)`` turns.
      Waves take at most one turn per slot (same-slot arrivals defer to
      later waves in admission order) and execute through the engine's
      split wave contract, overlapping wave *t+1*'s probe with wave *t*'s
      back-end search when ``overlap=True``.
    * **fn mode** (``fn=``): items are opaque; each wave is one
      ``fn(items) -> results`` call, one result per item in order (a
      result that is an exception instance fails only its own waiter;
      ``fn`` raising fails the wave).

    ``window_s > 0`` enables the deprecated hold-for-window admission
    serve_bench's fixed-window baseline uses; the continuous default is
    ``window_s = 0``.
    """

    def __init__(self, engine=None, *, fn: Optional[Callable] = None,
                 min_wave: int = 1, max_wave: Optional[int] = None,
                 window_s: float = 0.0, adaptive: Optional[bool] = None,
                 headroom: float = 1.5, ewma_horizon_s: float = 1.0,
                 target_p99_s: Optional[float] = None,
                 overlap: bool = True,
                 telemetry: Optional[ServeTelemetry] = None):
        if (engine is None) == (fn is None):
            raise ValueError("pass exactly one of engine= or fn=")
        self._engine = engine
        self._fn = fn
        if max_wave is None:
            max_wave = engine.n_sessions if engine is not None else 64
        if not (1 <= min_wave <= max_wave):
            raise ValueError(f"need 1 <= min_wave <= max_wave, got "
                             f"[{min_wave}, {max_wave}]")
        self.min_wave, self.max_wave = min_wave, max_wave
        self.window_s = window_s
        self.headroom = headroom
        self.target_p99_s = target_p99_s
        self.adaptive = (engine is not None) if adaptive is None else adaptive
        self.overlap = overlap and engine is not None
        self.telemetry = telemetry if telemetry is not None else (
            getattr(engine, "telemetry", None) or ServeTelemetry(
                ewma_horizon_s=ewma_horizon_s))
        self.wave_limit = max_wave      # cold start: absorb bursts
        self._service_ewma = 0.0
        self._queue: list[_Item] = []
        self._active_slots: set = set()
        self._in_wave = 0               # waves taken but not yet resolved
        self._drain: set = set()
        self._flushes = 0               # flush() calls currently waiting
        self._closed = False
        self._cond = threading.Condition()
        self._backend_pool = (cf.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="sched-backend")
            if self.overlap else None)
        self._worker = threading.Thread(target=self._loop,
                                        name="sched-worker", daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- client
    def submit(self, payload, slot=None) -> cf.Future:
        """Admit one item; returns a Future resolved with its result.

        The admission timestamp is stamped here — queue wait (admission to
        wave start) is part of the turn's measured latency."""
        item = _Item(payload, slot)
        with self._cond:
            if self._closed:
                raise RuntimeError(f"{type(self).__name__} is closed")
            self._queue.append(item)
            self.telemetry.record_arrival()
            self._cond.notify_all()
        return item.future

    def flush(self):
        """Execute everything queued *now*; returns once those waves have
        resolved.  New arrivals during the flush may ride along."""
        with self._cond:
            if not self._queue and not self._in_wave:
                return
            self._flushes += 1
            self._cond.notify_all()
            try:
                while self._queue or self._in_wave:
                    if self._closed and not self._worker.is_alive():
                        break
                    self._cond.wait(timeout=0.05)
            finally:
                self._flushes -= 1

    def drain_slot(self, slot):
        """Execute only ``slot``'s pending turns (bypassing any window
        hold) and return once none remain queued or in flight.  Other
        sessions' queued turns keep waiting on their own schedule — this
        is the per-key drain ``SessionManager.close`` uses instead of a
        global flush."""
        with self._cond:
            self._drain.add(slot)
            self._cond.notify_all()
            try:
                while (slot in self._active_slots
                       or any(it.slot == slot for it in self._queue)):
                    if self._closed and not self._worker.is_alive():
                        break
                    self._cond.wait(timeout=0.05)
            finally:
                self._drain.discard(slot)
                self._cond.notify_all()

    def close(self):
        """Drain the queue, stop the worker, release the back-end thread.
        Idempotent; ``submit`` afterwards raises."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._worker is not threading.current_thread():
            self._worker.join()
        if self._backend_pool is not None:
            self._backend_pool.shutdown(wait=True)

    def __enter__(self) -> "ContinuousScheduler":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    @property
    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # ----------------------------------------------------- sizing policy
    def _target_limit(self, rate: float, service_s: float,
                      p99_s: Optional[float] = None) -> int:
        """Wave bucket / active-slot limit from arrival-rate telemetry.

        Little's-law sizing: at ``rate`` arrivals/sec and ``service_s``
        per wave, ``rate * service_s`` turns land during one wave —
        that (x headroom) is the bucket that absorbs the steady state,
        rounded up to the engine's power-of-two jit buckets.  A measured
        turn p99 above ``target_p99_s`` backs the limit off one bucket
        step (smaller waves finish sooner) until the SLO recovers.
        """
        target = rate * max(service_s, 1e-4) * self.headroom
        b = 1
        while b < target and b < self.max_wave:
            b *= 2
        limit = max(self.min_wave, min(b, self.max_wave))
        if (self.target_p99_s is not None and p99_s is not None
                and p99_s == p99_s and p99_s > self.target_p99_s):
            limit = min(limit, max(self.min_wave, self.wave_limit // 2))
        return limit

    def _adapt_locked(self) -> None:
        if not self.adaptive or self.telemetry.arrivals.count < 8:
            return
        p99 = (self.telemetry.spans["total_s"].percentile(99)
               if self.target_p99_s is not None else None)
        self.wave_limit = self._target_limit(
            self.telemetry.arrivals.rate(), self._service_ewma, p99)

    # ---------------------------------------------------- wave selection
    def _select_locked(self):
        """Pick the next wave from the queue (caller holds the lock).

        Returns ``(batch, wait_s)``: a non-empty list of items removed
        from the queue, or ``(None, wait_s)`` when nothing is ready —
        ``wait_s`` is how long to sleep for a pending window hold (None =
        until notified).
        """
        eligible: list[_Item] = []
        seen_slots: set = set()
        for it in self._queue:
            if it.slot is not None:
                if it.slot in self._active_slots or it.slot in seen_slots:
                    seen_slots.add(it.slot)   # preserve per-slot order:
                    continue                  # later items of it stay too
                seen_slots.add(it.slot)
            eligible.append(it)
            if len(eligible) >= self.wave_limit:
                break
        if not eligible:
            return None, None
        drain_ready = [it for it in eligible if it.slot in self._drain]
        drain_only = False
        ready = (self.window_s <= 0 or self._closed or self._flushes > 0
                 or len(self._queue) >= self.wave_limit
                 or any(it.released for it in eligible))
        if not ready:
            age = time.perf_counter() - eligible[0].admitted_at
            if age >= self.window_s:
                ready = True
            elif drain_ready:
                # a drain bypasses the hold for ITS slot only: other
                # sessions' turns keep waiting on their own window
                eligible = drain_ready
                drain_only = True
            else:
                return None, self.window_s - age
        batch = eligible
        taken = set(map(id, batch))
        self._queue = [it for it in self._queue if id(it) not in taken]
        if not drain_only:
            for it in self._queue:
                # a window-mode flush takes the whole queue: anything
                # already admitted when this wave fired owes no further hold
                it.released = True
        for it in batch:
            if it.slot is not None:
                self._active_slots.add(it.slot)
        self._in_wave += 1
        return batch, None

    # ------------------------------------------------------- worker loop
    def _loop(self):
        inflight: Optional[_Inflight] = None
        while True:
            batch = None
            with self._cond:
                while True:
                    batch, wait_s = self._select_locked()
                    if batch is not None or inflight is not None:
                        break
                    if self._closed and not self._queue:
                        self._cond.notify_all()
                        return
                    self._cond.wait(timeout=wait_s)
            nxt = None
            if batch is not None:
                if self._engine is None:
                    self._run_fn_wave(batch)
                else:
                    # probe wave t+1 NOW: it only reads cache state, and
                    # wave t's back-end search is still in flight
                    nxt = self._begin_wave(batch)
            if inflight is not None:
                self._finish_wave(inflight)
            inflight = nxt

    # ------------------------------------------------------ fn-mode wave
    def _run_fn_wave(self, batch: list) -> None:
        t0 = time.perf_counter()
        items = [it.payload for it in batch]
        try:
            results = self._fn(items)
            if len(results) != len(batch):
                raise RuntimeError(
                    f"batch fn returned {len(results)} results for "
                    f"{len(batch)} items")
        except Exception as e:                 # noqa: BLE001
            for it in batch:
                it.future.set_exception(e)
        else:
            for it, res in zip(batch, results):
                if isinstance(res, BaseException):
                    it.future.set_exception(res)
                else:
                    it.future.set_result(res)
        self._wave_done(batch, time.perf_counter() - t0)

    # -------------------------------------------------- engine-mode wave
    def _begin_wave(self, batch: list) -> Optional[_Inflight]:
        """Run the probe phase of a wave; launch its back-end search on
        the side thread when overlapping."""
        t0 = time.perf_counter()
        try:
            ws = self._engine.probe_wave(
                [it.slot for it in batch], [it.payload for it in batch],
                admitted_at=[it.admitted_at for it in batch])
        except Exception as e:                 # noqa: BLE001
            for it in batch:
                it.future.set_exception(e)
            self._wave_done(batch, time.perf_counter() - t0)
            return None
        backend_future = (self._backend_pool.submit(
            self._engine.backend_wave, ws) if self.overlap else None)
        return _Inflight(ws, batch, backend_future, t0)

    def _finish_wave(self, infl: _Inflight) -> None:
        """Join the back-end phase, run the fill phase, resolve waiters.
        An engine exception fails this wave's futures only — the loop
        never wedges."""
        try:
            if infl.backend_future is not None:
                infl.backend_future.result()
            else:
                self._engine.backend_wave(infl.ws)
            turns = self._engine.fill_wave(infl.ws)
        except Exception as e:                 # noqa: BLE001
            for it in infl.items:
                it.future.set_exception(e)
        else:
            for it, res in zip(infl.items, turns):
                if isinstance(res, BaseException):
                    it.future.set_exception(res)
                else:
                    it.future.set_result(res)
        self._wave_done(infl.items, time.perf_counter() - infl.t_start)

    def _wave_done(self, batch: list, service_s: float) -> None:
        self.telemetry.record_wave(len(batch), service_s)
        alpha = 0.3
        self._service_ewma = (service_s if self._service_ewma == 0.0 else
                              (1 - alpha) * self._service_ewma
                              + alpha * service_s)
        with self._cond:
            for it in batch:
                if it.slot is not None:
                    self._active_slots.discard(it.slot)
            self._in_wave -= 1
            self._adapt_locked()
            self._cond.notify_all()

