"""Deterministic fault injection for the serving stack.

Resilience claims are only as good as the failures they were tested
against, and ad-hoc `time.sleep` lambdas in tests do not compose into a
committed, reproducible chaos schedule.  This module is the single fault
model shared by the unit tests, ``serve_bench --chaos``, and the example:

  * ``FaultSpec`` — one fault behavior on a *call-count* schedule: the
    spec is active for calls in ``[start, stop)`` whose phase within
    ``period`` falls inside ``width``.  ``period=1`` makes a solid
    outage window; ``width < period`` makes a flapping or every-Nth
    pattern.  Schedules key on the wrapped shard's own call counter, so
    a run is bit-reproducible regardless of wall clock or thread timing.
  * ``FaultyShard`` — wraps one shard callable ``(queries, k) ->
    ShardAnswer`` and applies its specs per call: latency spikes
    (``latency``; a spike past the router deadline IS a timeout),
    raised exceptions / flapping outages (``error``), and *corrupt*
    answers (``corrupt``): NaN or +inf scores, out-of-range ids, or
    wrong shapes — the poison the router's answer validation must stop
    before ``_merge`` ranks on it.
  * ``FaultPlan`` — a seeded schedule over a whole shard set;
    ``plan.wrap(shards)`` returns the faulty fleet (every shard is
    wrapped, spec-less ones as transparent call counters).
  * ``chaos_plan`` — the COMMITTED chaos schedule CI gates: shard 0
    flaps (two outage windows, so its breaker must open, half-open
    probe, re-close, and re-open), shard 1 spikes latency, shard 2
    returns corrupt answers rotating through every corruption mode,
    remaining shards stay healthy (so availability is answerable
    throughout).

Corruption payloads derive from ``numpy.random.default_rng((seed,
call))`` — deterministic per (plan seed, call index), independent of
call interleaving across shards.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from repro.serve.router import ShardAnswer

__all__ = ["FaultError", "FaultSpec", "FaultyShard", "FaultPlan",
           "chaos_plan", "CORRUPT_MODES"]

CORRUPT_MODES = ("nan", "inf", "oob", "shape")


class FaultError(RuntimeError):
    """The exception an injected ``error`` fault raises."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault behavior on a deterministic call-count schedule.

    Active for call index ``c`` iff ``start <= c`` (``< stop`` when
    ``stop`` is set) and ``(c - start) % period < width``.
    """

    kind: str                    # "latency" | "error" | "corrupt"
    start: int = 0               # first affected call index
    stop: Optional[int] = None   # half-open end of the window (None: ever)
    period: int = 1              # schedule cycle inside the window
    width: int = 1               # active calls per cycle (flap duty)
    delay_s: float = 0.0         # latency kind: injected sleep
    mode: str = "nan"            # corrupt kind: CORRUPT_MODES or "mix"

    def __post_init__(self):
        if self.kind not in ("latency", "error", "corrupt"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.period < 1 or not (1 <= self.width <= self.period):
            raise ValueError("need period >= 1 and 1 <= width <= period")
        if self.kind == "corrupt" and self.mode not in \
                CORRUPT_MODES + ("mix",):
            raise ValueError(f"unknown corrupt mode {self.mode!r}")

    def active(self, call: int) -> bool:
        """Whether this spec fires on the given call index."""
        if call < self.start:
            return False
        if self.stop is not None and call >= self.stop:
            return False
        return (call - self.start) % self.period < self.width


def _corrupt(ans: ShardAnswer, mode: str, seed: int, call: int
             ) -> ShardAnswer:
    """Poison a well-formed answer the way a broken shard would."""
    rng = np.random.default_rng((seed, call))
    if mode == "mix":
        mode = CORRUPT_MODES[call % len(CORRUPT_MODES)]
    scores = np.array(ans.scores, np.float32, copy=True)
    ids = np.array(ans.ids, copy=True)
    if mode == "nan":
        cols = rng.integers(0, scores.shape[1], max(1, scores.shape[1] // 4))
        scores[:, cols] = np.nan
    elif mode == "inf":
        scores[:, 0] = np.inf
    elif mode == "oob":
        # ids far outside any corpus (and one below the -1 sentinel)
        ids[:, 0] = 2 ** 40
        if ids.shape[1] > 1:
            ids[:, 1] = -7
    elif mode == "shape":
        # transposed result: (k, B) where (B, k) is owed
        scores, ids = scores.T, ids.T
    return ShardAnswer(scores, ids)


class FaultyShard:
    """One shard callable wrapped with a deterministic fault schedule.

    Thread-safe: concurrent calls (hedges, retries) each draw a distinct
    call index.  A spec-less wrapper is a transparent pass-through that
    still counts calls — useful for asserting a shard was (not) called.
    """

    def __init__(self, inner: Callable, specs: Sequence[FaultSpec] = (),
                 *, seed: int = 0):
        self.inner = inner
        self.specs = tuple(specs)
        self.seed = seed
        self.calls = 0
        self.faults = 0              # calls on which any spec fired
        self._lock = threading.Lock()

    def __call__(self, queries, k):
        with self._lock:
            call = self.calls
            self.calls += 1
        active = [s for s in self.specs if s.active(call)]
        if active:
            with self._lock:
                self.faults += 1
        for s in active:             # latency composes with the others
            if s.kind == "latency":
                time.sleep(s.delay_s)
        for s in active:
            if s.kind == "error":
                raise FaultError(
                    f"injected outage (call {call}, spec {s.kind})")
        ans = self.inner(queries, k)
        for s in active:
            if s.kind == "corrupt":
                ans = _corrupt(ans, s.mode, self.seed, call)
        return ans


class FaultPlan:
    """A seeded fault schedule over a whole shard fleet.

    ``specs``: mapping shard index -> sequence of ``FaultSpec``.  The
    plan is data; ``wrap(shards)`` instantiates it over concrete shard
    callables (every shard wrapped, so per-shard call counts are always
    observable via ``plan.wrapped``).
    """

    def __init__(self, specs: Mapping[int, Sequence[FaultSpec]],
                 seed: int = 0):
        self.specs = {int(i): tuple(v) for i, v in specs.items()}
        self.seed = seed
        self.wrapped: list[FaultyShard] = []

    def wrap(self, shards: Sequence[Callable]) -> list:
        """Wrap the fleet; returns the faulty shard callables."""
        self.wrapped = [
            FaultyShard(s, self.specs.get(i, ()), seed=self.seed + i)
            for i, s in enumerate(shards)]
        return list(self.wrapped)

    def calls(self) -> list:
        """Per-shard call counts of the last wrapped fleet."""
        return [w.calls for w in self.wrapped]


def chaos_plan(n_shards: int, *, seed: int = 0, spike_s: float = 0.05,
               flap_down: int = 6, flap_up: int = 8) -> FaultPlan:
    """The committed chaos schedule the CI gate replays.

    * shard 0 — flapping outage: healthy warm-up (4 calls), then two
      ``flap_down``-call outage windows separated by ``flap_up`` healthy
      calls; its breaker must open, probe, re-close, and survive the
      second window.
    * shard 1 — latency spikes: every 3rd call sleeps ``spike_s`` (size
      it against the router deadline to exercise hedging or timeouts).
    * shard 2 — corrupt answers: every other call in a long window,
      rotating through every corruption mode (NaN, +inf, out-of-range
      ids, transposed shapes) so each validation path is exercised.
    * shards 3+ — healthy: the degraded merges stay answerable, keeping
      warm-session availability at the >= 0.99 gate.
    """
    if n_shards < 3:
        raise ValueError("chaos_plan needs >= 3 shards "
                         "(flapping / spiking / corrupt)")
    w0 = 4 + flap_down          # end of shard 0's first outage window
    return FaultPlan({
        0: (FaultSpec("error", start=4, stop=w0),
            FaultSpec("error", start=w0 + flap_up,
                      stop=w0 + flap_up + flap_down)),
        1: (FaultSpec("latency", start=2, period=3, delay_s=spike_s),),
        2: (FaultSpec("corrupt", start=2, stop=60, period=2, mode="mix"),),
    }, seed=seed)
