"""Serving telemetry: per-turn latency spans and streaming percentiles.

The paper's case is *latency* — the cache exists so a conversational turn
answers fast — so the serving tier must be able to state a p99 for a
single turn, not just a closed-loop throughput.  This module is the
measurement substrate the continuous scheduler and ``serve_bench``'s
open-loop harness share:

  * ``TurnSpans`` — one turn's latency decomposition: queue wait
    (admission -> wave start), probe (L1/L2 cache launches), backend
    (router round-trip over the miss subset), insert (fused insert+query
    close), and the admission-to-resolution total.  Spans other than
    queue wait are wave-level (every turn of a wave shares them); the
    queue wait and total are strictly per turn.
  * ``RingPercentiles`` — a fixed-capacity ring buffer with nearest-rank
    percentile estimates over the most recent window.  O(1) insertion on
    the serving path; sorting is deferred to ``percentile()``/
    ``summary()`` (telemetry readers, not the hot loop).
  * ``EwmaRate`` — an exponentially weighted arrival-rate estimator whose
    smoothing follows a wall-clock *horizon* (irregular arrival spacing is
    handled by weighting each observation with ``1 - exp(-dt/horizon)``).
    The scheduler sizes wave buckets and active engine slots from it.
  * ``ServeTelemetry`` — the aggregate the engine/scheduler write into:
    one ring per span kind, one ring of totals per serving tier
    (l1 / l2 / l2_reuse / backend), wave-size and wave-service histories,
    and a ``summary()`` that flattens to the p50/p95/p99 columns
    ``BENCH_serve.json`` commits and ``check_regression.py`` gates.

Everything here is plain host-side Python — no jax imports — so recording
a span never touches the device or the trace cache.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Optional

__all__ = ["TurnSpans", "RingPercentiles", "EwmaRate", "ServeTelemetry",
           "TIERS"]

TIERS = ("l1", "l2", "l2_reuse", "backend")


@dataclasses.dataclass
class TurnSpans:
    """One turn's latency decomposition, all in seconds.

    ``total_s`` is admission-to-resolution — the honest per-turn SLO
    number (satellite fix: a wave's turns used to all report the wave's
    wall clock, with queue wait invisible).
    """

    queue_wait_s: float = 0.0
    probe_s: float = 0.0
    backend_s: float = 0.0
    insert_s: float = 0.0
    total_s: float = 0.0
    tier: str = "backend"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class RingPercentiles:
    """Fixed-capacity ring of floats with nearest-rank percentiles.

    The ring keeps the most recent ``capacity`` observations (a serving
    process runs forever; an unbounded list would not).  Percentiles use
    the nearest-rank method on a sorted copy of the valid window —
    deterministic, exact over the window, and only paid when read.
    """

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError("RingPercentiles capacity must be positive")
        self.capacity = capacity
        self._buf = [0.0] * capacity
        self._n = 0          # monotone total ever added
        self._lock = threading.Lock()

    def add(self, x: float) -> None:
        with self._lock:
            self._buf[self._n % self.capacity] = float(x)
            self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def count(self) -> int:
        """Monotone total of observations ever recorded (window may hold
        fewer)."""
        return self._n

    def _window(self) -> list:
        with self._lock:
            m = min(self._n, self.capacity)
            return self._buf[:m]

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the current window (NaN if empty).

        ``p`` in [0, 100].
        """
        xs = sorted(self._window())
        if not xs:
            return float("nan")
        rank = max(1, math.ceil(p / 100.0 * len(xs)))
        return xs[min(rank, len(xs)) - 1]

    def summary(self) -> dict:
        """p50/p95/p99 + mean + count in one sorted pass."""
        xs = sorted(self._window())
        if not xs:
            return {"count": self._n, "mean": float("nan"),
                    "p50": float("nan"), "p95": float("nan"),
                    "p99": float("nan")}

        def at(p):
            rank = max(1, math.ceil(p / 100.0 * len(xs)))
            return xs[min(rank, len(xs)) - 1]

        return {"count": self._n, "mean": sum(xs) / len(xs),
                "p50": at(50), "p95": at(95), "p99": at(99)}


class EwmaRate:
    """Arrival-rate estimator (events/sec) with a wall-clock horizon.

    Each ``observe()`` folds the instantaneous rate ``1/dt`` into the
    estimate with weight ``1 - exp(-dt / horizon_s)`` — the continuous-time
    EWMA, so the effective memory is ``horizon_s`` seconds of traffic no
    matter how bursty the arrival spacing is.  The first observation only
    arms the clock (a single event has no rate).

    ``rate()`` additionally decays the estimate by the silence since the
    last event, so a stream that stops reads as a falling rate instead of
    freezing at its last busy value.
    """

    def __init__(self, horizon_s: float = 1.0,
                 clock=time.monotonic):
        if horizon_s <= 0:
            raise ValueError("EwmaRate horizon must be positive")
        self.horizon_s = horizon_s
        self._clock = clock
        self._rate = 0.0
        self._last: Optional[float] = None
        self._lock = threading.Lock()
        self.count = 0          # observations ever folded in

    def observe(self, t: Optional[float] = None) -> None:
        now = self._clock() if t is None else t
        with self._lock:
            self.count += 1
            if self._last is None:
                self._last = now
                return
            dt = max(now - self._last, 1e-9)
            self._last = now
            w = 1.0 - math.exp(-dt / self.horizon_s)
            self._rate += w * (1.0 / dt - self._rate)

    def rate(self, t: Optional[float] = None) -> float:
        """Current estimate in events/sec, decayed for elapsed silence."""
        now = self._clock() if t is None else t
        with self._lock:
            if self._last is None:
                return 0.0
            silence = max(now - self._last, 0.0)
            return self._rate * math.exp(-silence / self.horizon_s)


class ServeTelemetry:
    """Aggregate serving telemetry: spans, per-tier totals, wave shape.

    Writers: ``BatchedEngine.fill_wave`` records one ``TurnSpans`` per
    resolved turn; ``ContinuousScheduler`` records arrivals (for the EWMA)
    and per-wave (size, service seconds) samples.  Readers: the
    scheduler's sizing policy (``arrivals.rate()``, ``wave_service``),
    ``serve_bench``'s open-loop harness, and operators via ``summary()``.
    """

    SPAN_KEYS = ("queue_wait_s", "probe_s", "backend_s", "insert_s",
                 "total_s")

    def __init__(self, capacity: int = 4096, ewma_horizon_s: float = 1.0):
        self.spans = {k: RingPercentiles(capacity) for k in self.SPAN_KEYS}
        self.tier_total = {t: RingPercentiles(capacity) for t in TIERS}
        self.arrivals = EwmaRate(ewma_horizon_s)
        self.wave_sizes = RingPercentiles(capacity)
        self.wave_service = RingPercentiles(capacity)
        self.turns = 0
        self.waves = 0
        # fault-domain counters (breaker transitions, shed / degraded /
        # rejected-answer / stale-served / quarantined events) — written
        # by the router and engine, read by serve_bench --chaos
        self.faults: dict = {}
        self.breaker_log: list = []      # (shard, old_state, new_state)
        self.breaker_transitions = 0     # monotone (the log is bounded)
        self._fault_lock = threading.Lock()

    # ------------------------------------------------------------ writers
    def record_arrival(self, t: Optional[float] = None) -> None:
        self.arrivals.observe(t)

    def record_fault(self, kind: str, n: int = 1) -> None:
        """Count one fault-domain event (``shed_waves``, ``shed_turns``,
        ``degraded_turns``, ``rejected_answers``, ``stale_served``,
        ``quarantined_slots``, ``failed_turns``, ...)."""
        with self._fault_lock:
            self.faults[kind] = self.faults.get(kind, 0) + n

    def record_breaker(self, shard: int, old: str, new: str) -> None:
        """Log one circuit-breaker transition (bounded log + counters)."""
        with self._fault_lock:
            self.breaker_transitions += 1
            self.faults[f"breaker_{new}"] = \
                self.faults.get(f"breaker_{new}", 0) + 1
            if len(self.breaker_log) < 1024:
                self.breaker_log.append((shard, old, new))

    def record_turn(self, spans: TurnSpans) -> None:
        self.turns += 1
        for k in self.SPAN_KEYS:
            self.spans[k].add(getattr(spans, k))
        ring = self.tier_total.get(spans.tier)
        if ring is not None:
            ring.add(spans.total_s)

    def record_wave(self, size: int, service_s: float) -> None:
        self.waves += 1
        self.wave_sizes.add(float(size))
        self.wave_service.add(service_s)

    # ------------------------------------------------------------ readers
    def summary(self) -> dict:
        """Nested summary: per-span and per-tier p50/p95/p99 (+ wave
        shape).  Latency values stay in seconds; presentation layers
        (serve_bench) convert to ms."""
        with self._fault_lock:
            faults = dict(self.faults)
            transitions = self.breaker_transitions
        return {
            "turns": self.turns,
            "waves": self.waves,
            "arrival_rate_hz": self.arrivals.rate(),
            "spans": {k: r.summary() for k, r in self.spans.items()},
            "tiers": {t: r.summary() for t, r in self.tier_total.items()
                      if len(r)},
            "wave_size": self.wave_sizes.summary(),
            "wave_service_s": self.wave_service.summary(),
            "faults": faults,
            "breaker_transitions": transitions,
        }
