"""Session-batched serving: many concurrent conversational sessions per wave.

The single-session ``ConversationalEngine`` pays one encoder call, one cache
probe, one router round-trip, and one cache query *per turn*.  Under heavy
traffic the same work batches: ``BatchedEngine`` holds one stacked
``CacheState`` for S session slots and answers a wave of concurrent turns
with

  * one (batched) encoder call,
  * one ``probe_batched`` over the wave's cache slices,
  * one ``router.search`` for the whole miss subset (the paper batches 216
    queries into FAISS for the same reason), scattered back per session,
  * one ``insert_query_batched`` — the gated insert (per-session
    ``do``/``record`` masks) FUSED with the answer query.

On the kernel dispatch tiers every one of those cache steps is a single
Pallas launch, so a whole L1-only wave is exactly THREE kernel launches —
probe -> miss-search -> insert+query — with no vmap-of-scalar fallback (a
missless wave is two: probe -> query).  Per session the cache ops match
the scalar ops bit for bit on every tier, so a wave produces results
identical to running a sequential ``ConversationalEngine`` loop over the
same turn stream (tested).  One semantic difference is inherent to
batching: the router degrades per *call*, so a degraded back-end wave
marks every miss in that wave degraded (and, like the sequential engine,
skips their (psi, r_a) records so the caches are never poisoned).

**Split wave contract.**  A wave executes in three explicit phases so the
continuous scheduler can pipeline them across waves:

  * ``probe_wave``   — encoder + L1 probe + (tiered) L2 memo / shard
    probe.  Touches only cache state; never mutates L1.
  * ``backend_wave`` — ``router.search`` over the residual miss subset
    (host + router threads only; the miss-search kernel launch happens
    inside the router's shards).
  * ``fill_wave``    — the fused insert+query launch, the L1 scatter, the
    shared-tier admission flush, and per-turn ``EngineTurn`` assembly.

``answer_batch`` is exactly ``probe -> backend -> fill`` run inline, so
its kernel-launch contract is unchanged (3 launches L1-only, 4 tiered
full-miss).  Under the scheduler, wave *t+1*'s probe overlaps wave *t*'s
back-end search: the probe reads only cache state of *disjoint* session
slots (the scheduler admits at most one in-flight turn per slot), and all
cache launches stay on the scheduler's worker thread, so per-session
results remain bit-identical to the sequential engine.

**Cache hierarchy.**  With a ``repro.core.shared.SharedTier`` attached,
the miss wave becomes tiered: probe-L1 -> probe-L2 -> back-end search on
the residual misses -> insert both tiers.  L1 misses first try the shared
tier's semantic result memo (host-side; a near-duplicate query from
another session reuses its full result set), then the shared shard caches
via the SAME ``cache_probe_batched`` kernel over the gathered shard rows
— so a full-miss tiered wave is exactly FOUR launches (L1 probe -> L2
probe -> miss-search -> fused insert+query; an L2 answer query or an
end-of-wave admission flush adds one only when L2 actually serves or
promotes).  Every tier-served answer also warms the session's L1 cache
through the same fused insert+query launch, with the (psi, r_a) coverage
claim recorded only when it is sound: fresh un-degraded back-end radii,
or the memo's triangle-corrected Eq. 3 claim.

**Latency attribution.**  Each turn reports admission-to-resolution
latency (``EngineTurn.latency_s``) with its queue wait broken out
(``queue_wait_s``) and the wave-level probe / backend / insert spans
attached (``spans``, a ``repro.serve.telemetry.TurnSpans``).  A wave used
to stamp every member with the whole wave's wall clock and queue wait was
invisible — SLO numbers were unmeasurable.

``SessionManager`` puts an asynchronous front door on the engine: it maps
external session keys to engine slots and admits ``submit``-ed turns into
continuously scheduled waves via ``repro.serve.scheduler
.ContinuousScheduler`` — callers get a Future per turn, resolved when its
wave's fill phase completes.  ``close(key)`` drains only that key's
pending turns (per-slot drain); it no longer flushes the global queue.
It is a context manager: leaving the ``with`` block (or calling
``shutdown()``) drains pending turns and stops the scheduler's worker.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cache import (BatchedMetricCache, CacheConfig,
                              insert_query_batched, probe_batched,
                              query_batched, validate_state)
from repro.core.embedding import distance_from_scores
from repro.core.shared import SharedTier
from repro.kernels import dispatch as kdispatch
from repro.serve.engine import EngineTurn
from repro.serve.router import ShardedRouter
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.telemetry import ServeTelemetry, TurnSpans

__all__ = ["BatchedEngine", "SessionManager", "WaveState"]


@dataclasses.dataclass
class WaveState:
    """One wave in flight between the probe, backend, and fill phases.

    Buffers are bucket-sized (the wave padded to its power-of-two jit
    bucket); masks carry which rows are real, which still need the
    back-end, and which tier answered each.  ``admitted_at`` holds the
    per-turn admission stamps the latency attribution derives from.
    """

    sids: np.ndarray                 # (wave,) real session slots
    pad_sids: np.ndarray             # (bucket,) padded slot row
    wave: int
    bucket: int
    psi: jax.Array                   # (bucket, dim) transformed queries
    psi_np: np.ndarray
    sub: object                      # gathered CacheState rows
    need: np.ndarray                 # (bucket,) rows still needing backend
    tier: np.ndarray                 # (bucket,) serving tier per row
    reuse: np.ndarray                # (bucket,) L2 memo reuse rows
    l2hit: np.ndarray                # (bucket,) L2 shard-probe hit rows
    new_ids: np.ndarray              # (bucket, k_c + prefetch_width) inserts
    new_emb: np.ndarray              # (bucket, k_c + prefetch_width, dim)
    rad: np.ndarray                  # (bucket,) claim radii
    rec_np: np.ndarray               # (bucket,) record the (psi, r_a) claim
    backend_ok: np.ndarray           # (bucket,) rows the backend answered
    failed: np.ndarray               # (bucket,) empty-cache outage rows
    stale: np.ndarray                # (bucket,) stale-while-error memo rows
    admitted_at: np.ndarray          # (wave,) perf_counter admission stamps
    t_start: float                   # wave (probe-phase) start stamp
    degraded: bool = False
    shed: bool = False               # back end fenced: load-shed wave
    outage: Optional[BaseException] = None
    probe_s: float = 0.0
    backend_s: float = 0.0


class BatchedEngine:
    """S concurrent client sessions over one stacked metric cache."""

    def __init__(self, router: ShardedRouter, doc_embeddings: np.ndarray,
                 *, dim: int, n_sessions: int, k: int = 10, k_c: int = 1000,
                 epsilon: float = 0.04, capacity: Optional[int] = None,
                 encoder: Optional[Callable] = None,
                 dtype: Optional[str] = None,
                 backend: Optional[str] = None,
                 shared: Optional[SharedTier] = None,
                 cluster=None, prefetch_width: int = 0,
                 telemetry: Optional[ServeTelemetry] = None,
                 validate_every: int = 0):
        self.router = router
        self.doc_embeddings = doc_embeddings
        self.n_sessions = n_sessions
        self.k, self.k_c, self.epsilon = k, k_c, epsilon
        self.encoder = encoder
        # cluster + prefetch_width: the topical-locality prefetch path
        # (repro.core.cluster).  On a backend miss the fill phase appends
        # up to prefetch_width nearest-to-centroid docs to the answer
        # inside the SAME fused insert+query launch, and widens the
        # recorded claim by the triangle inequality (see fill_wave).
        self.cluster = cluster
        self.prefetch_width = int(prefetch_width) if cluster is not None else 0
        if self.cluster is not None \
                and self.prefetch_width > self.cluster.max_width:
            raise ValueError(
                f"prefetch_width {self.prefetch_width} exceeds the cluster "
                f"index's neighbor tables (max_width {self.cluster.max_width})")
        # per-slot ids brought in by prefetch (for warm-hit attribution)
        self._prefetched: list[set] = [set() for _ in range(n_sessions)]
        self.prefetch_issued = 0       # docs inserted via prefetch
        self.prefetch_warm_hits = 0    # prefetched docs in cache-served results
        self.insert_traffic_docs = 0   # docs offered to the L1 insert launch
        # backend: the kernels.dispatch tier the wave's cache ops run on
        # (None follows the process default — compiled Pallas on TPU, jnp
        # ref elsewhere).  Resolved once so every wave of this engine rides
        # one tier.
        self.backend = kdispatch.resolve(backend)
        # dtype: stacked-cache storage format (quant.DTYPES; None follows
        # the REPRO_CORPUS_DTYPE policy).  S sessions' caches share one
        # device allocation, so a bf16 / int8 store cuts the resident
        # serving state 2x / 4x.
        self.cache = BatchedMetricCache(CacheConfig(
            capacity=capacity or 16 * k_c, dim=dim, epsilon=epsilon,
            store_dtype=quant.resolve_dtype(dtype)),
            n_sessions)
        # shared: the optional cross-session L2 tier (None = the paper's
        # private-cache model).  Probe order: L1 -> L2 memo -> L2 shards ->
        # back-end.
        self.shared = shared
        if shared is not None:
            assert shared.cfg.dim == dim, "shared tier dim mismatch"
        # the shared tier's host structures are touched from the probe/fill
        # phases (scheduler worker) AND the backend phase (side thread)
        # when waves overlap; its sections serialize on this lock
        self._shared_lock = threading.Lock()
        self.telemetry = telemetry if telemetry is not None \
            else ServeTelemetry()
        # validate_every: run the cache_ops.validate_state integrity check
        # over the stacked caches every N waves (0 disables) and
        # quarantine-reset any slot whose invariants are broken, instead
        # of letting a corrupted slot poison (or crash) its next wave
        self.validate_every = int(validate_every)
        self.quarantined = 0
        self._waves = 0
        self.turns: list[list[EngineTurn]] = [[] for _ in range(n_sessions)]
        # admission identity: (slot, generation) — bumped on start_session
        # so a recycled slot never inherits its predecessor's popularity
        # votes in the shared tier's >= 2-distinct-sessions counts
        self._gen = np.zeros((n_sessions,), np.int64)

    def start_session(self, session: int):
        self.cache.reset([session])
        self.turns[session] = []
        self._prefetched[session].clear()
        self._gen[session] += 1

    def quarantine_invalid(self) -> np.ndarray:
        """Integrity sweep: run ``cache_ops.validate_state`` over the
        stacked session caches and QUARANTINE any slot whose invariants
        are broken — the slot is reset to an empty cache (its next turn
        is a compulsory miss) instead of the corruption poisoning or
        crashing the wave.  Returns the reset slot indices.  Runs
        automatically every ``validate_every`` waves when that knob is
        set; callable directly after any suspected corruption."""
        ok, _problems = validate_state(self.cache.state, self.cache.cfg,
                                       n_corpus=len(self.doc_embeddings))
        bad = np.nonzero(~np.asarray(ok))[0]
        if bad.size:
            self.cache.reset(bad.tolist())
            for s in bad:
                self._prefetched[int(s)].clear()
            self.quarantined += int(bad.size)
            self.telemetry.record_fault("quarantined_slots", int(bad.size))
        return bad

    def _token(self, slot) -> tuple:
        """The slot's current admission identity for the shared tier."""
        return (int(slot), int(self._gen[int(slot)]))

    def _bucket(self, n: int) -> int:
        """Pad wave sizes to powers of two (capped at n_sessions): the
        batched ops are jitted per shape, so free-running traffic would
        otherwise pay a fresh XLA compile for every distinct wave size."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.n_sessions)

    # ------------------------------------------------------- probe phase
    def probe_wave(self, sessions, queries,
                   admitted_at: Optional[Sequence[float]] = None
                   ) -> WaveState:
        """Phase 1 of a wave: encoder + L1 probe + (tiered) L2 lookups.

        Touches only cache state — L1 rows are gathered and probed, the
        shared tier's memo and shard caches are consulted for L1 misses —
        and never writes L1, so it may run while the *previous* wave's
        back-end search is still in flight (the scheduler guarantees the
        two waves' session slots are disjoint).

        sessions: sequence of distinct session-slot indices.
        queries: matching sequence of raw queries (or pre-transformed psi
        when no encoder is configured).
        admitted_at: optional per-turn admission stamps
        (``time.perf_counter`` clock); defaults to now, i.e. zero queue
        wait for directly-invoked waves.
        """
        t_start = time.perf_counter()
        self._waves += 1
        if self.validate_every and self._waves % self.validate_every == 0:
            self.quarantine_invalid()
        sids = np.asarray(sessions, np.int32)
        if np.unique(sids).size != sids.size:
            raise ValueError("one turn per session per wave")
        wave = len(sids)
        bucket = self._bucket(wave)
        admitted = (np.full((wave,), t_start, np.float64)
                    if admitted_at is None
                    else np.asarray(admitted_at, np.float64))
        # pad the wave with copies of row 0 (probe-only: do/need are forced
        # False and padded rows are never scattered back or reported)
        pad_sids = np.concatenate([sids, np.repeat(sids[:1], bucket - wave)])
        q = jnp.stack([jnp.asarray(x) for x in queries])
        q = jnp.concatenate([q, jnp.broadcast_to(q[:1], (bucket - wave,)
                                                 + q.shape[1:])])
        psi = self.encoder(q) if self.encoder else q

        sub = self.cache.gather(pad_sids)
        # launch 1: the L1 LowQuality probe over the wave's session slices
        pr = probe_batched(sub, psi, self.epsilon, backend=self.backend,
                           max_queries=self.cache.cfg.max_queries)
        n_queries = np.asarray(sub.n_queries)
        need = np.logical_or(n_queries == 0, ~np.asarray(pr.hit))
        need[wave:] = False
        tier = np.where(need, "backend", "l1").astype(object)
        psi_np = np.asarray(psi)

        # rows that insert into L1 this wave fill these buffers; tier-served
        # rows (memo reuse / L2 shard hits) ride the same fused insert+query
        # launch as back-end rows, so warming L1 from L2 costs no extra
        # launch and every answer is re-scored against THIS query's psi
        reuse = np.zeros((bucket,), bool)
        l2hit = np.zeros((bucket,), bool)
        # insert buffers carry prefetch_width extra columns so the fill
        # phase can fold cluster neighbors into the same fused launch
        width = self.k_c + self.prefetch_width
        new_ids = np.full((bucket, width), -1, np.int64)
        new_emb = np.zeros((bucket, width, self.doc_embeddings.shape[1]),
                           self.doc_embeddings.dtype)
        rad = np.zeros((bucket,), np.float32)
        rec_np = np.zeros((bucket,), bool)

        if self.shared is not None:
            with self._shared_lock:
                self.shared.tick()
                if need.any():
                    need = self._probe_shared(pad_sids, psi, psi_np, need,
                                              reuse, l2hit, new_ids,
                                              new_emb, rad, rec_np)
            tier[reuse] = "l2_reuse"
            tier[l2hit] = "l2"

        ws = WaveState(
            sids=sids, pad_sids=pad_sids, wave=wave, bucket=bucket,
            psi=psi, psi_np=psi_np, sub=sub, need=need, tier=tier,
            reuse=reuse, l2hit=l2hit, new_ids=new_ids, new_emb=new_emb,
            rad=rad, rec_np=rec_np,
            backend_ok=np.zeros((bucket,), bool),
            failed=np.zeros((bucket,), bool),
            stale=np.zeros((bucket,), bool),
            admitted_at=admitted, t_start=t_start)
        ws.probe_s = time.perf_counter() - t_start
        return ws

    def _probe_shared(self, pad_sids, psi, psi_np, need, reuse, l2hit,
                      new_ids, new_emb, rad, rec_np) -> np.ndarray:
        """Tiered lookups for L1 misses (caller holds the shared lock).
        Returns the residual miss mask after memo reuse and L2 hits."""
        l2 = self.shared
        # L2a — semantic result reuse (host-side memo; no launch): a
        # near-duplicate query from ANOTHER session reuses its full
        # k_c result set, and records the triangle-corrected Eq. 3
        # claim r_a - delta(psi_a, psi) when it still clears epsilon
        for i in np.nonzero(need)[0]:
            m = l2.memo_lookup(self._token(pad_sids[i]), psi_np[i])
            if m is None:
                continue
            m_ids, _m_scores, claim = m
            reuse[i] = True
            n = min(self.k_c, m_ids.shape[0])
            new_ids[i, :n] = m_ids[:n]
            new_emb[i, :n] = self.doc_embeddings[
                np.maximum(m_ids[:n], 0)]
            if claim >= self.epsilon:
                rad[i] = claim
                rec_np[i] = True
            # the reusing session is a distinct retriever of these
            # docs — it counts toward the >= 2-sessions admission bar
            l2.offer(self._token(pad_sids[i]), psi_np[i], claim,
                     new_emb[i], new_ids[i])
        rem = np.logical_and(need, ~reuse)
        if rem.any():
            # L2b — launch 2: the SAME LowQuality probe kernel over the
            # gathered shard rows of the shared tier (whole bucket, one
            # jitted shape; results masked to the residual misses)
            shards = l2.route(psi_np)
            l2pr = l2.probe_rows(psi, shards, backend=self.backend)
            l2hit[:] = np.logical_and(np.asarray(l2pr.hit), rem)
            if l2hit.any():
                # covered by a shared claim: answer from the shard's
                # cached docs (one fused wave-query launch, only when
                # L2 actually serves someone)
                (_s2, _d2, i2, _sl2) = l2.query_rows(
                    psi, shards, self.k, backend=self.backend)
                i2_np = np.asarray(i2)
                for i in np.nonzero(l2hit)[0]:
                    row = i2_np[i][i2_np[i] >= 0]
                    n = min(self.k_c, row.shape[0])
                    new_ids[i, :n] = row[:n]
                    new_emb[i, :n] = self.doc_embeddings[row[:n]]
            return np.logical_and(rem, ~l2hit)
        return rem

    # ----------------------------------------------------- backend phase
    def backend_wave(self, ws: WaveState) -> WaveState:
        """Phase 2: ``router.search`` over the residual miss subset.

        Host + router work only (the miss-search kernel launch lives
        inside the router's shards), so the scheduler may run it on a side
        thread while the next wave probes.  A total back-end failure marks
        empty-cache miss rows failed; raises only when *every* real row in
        the wave is in that state (the same per-session failure a
        sequential engine loop raises).

        **Degradation ladder.**  When the router reports ``backend_open``
        (every shard's circuit breaker open) the wave is LOAD-SHED: the
        search — and its whole deadline wait — is skipped, and miss rows
        walk the same fallback ladder a failed search does: (1) a warm
        cache answers from cached embeddings, (2) an empty-cache row is
        served stale-while-error from the L2 memo (claims never
        recorded), (3) only a row with neither fails.  A shed wave with
        no tier-served rows runs probe -> query, the 2-launch contract
        (jaxpr-guarded in tests).
        """
        t0 = time.perf_counter()
        need, bucket, wave = ws.need, ws.bucket, ws.wave
        try:
            if need.any():
                if getattr(self.router, "backend_open", False):
                    ws.shed = True
                    self.telemetry.record_fault("shed_waves")
                    self.telemetry.record_fault(
                        "shed_turns", int(need[:wave].sum()))
                    self._outage_fallback(ws, TimeoutError(
                        "back end fenced: load-shed wave"))
                    if ws.failed[:wave].all():
                        raise ws.outage
                    return ws
                miss = np.nonzero(need)[0]
                try:
                    ans, degraded = self.router.search(
                        ws.psi_np[miss], self.k_c)
                    ws.degraded = degraded
                    n_valid = (ans.ids >= 0).sum(axis=1)
                    if (n_valid == 0).any():
                        raise TimeoutError(
                            "back-end answer holds no valid docs")
                    # r_a per row from the last *valid* column (short
                    # merges are sentinel-padded); same guard as the
                    # sequential engine
                    radii = np.asarray(distance_from_scores(jnp.asarray(
                        np.take_along_axis(ans.scores, n_valid[:, None] - 1,
                                           axis=1)[:, 0])))
                    ws.new_ids[miss, :self.k_c] = ans.ids
                    ws.new_emb[miss, :self.k_c] = self.doc_embeddings[
                        np.maximum(ans.ids, 0)]
                    ws.rad[miss] = radii
                    # a degraded merge is missing shards: keep the docs,
                    # skip the (psi, r_a) record so no cache learns a
                    # false claim
                    ws.rec_np[miss] = not degraded
                    ws.backend_ok = need.copy()
                    if self.shared is not None and not degraded:
                        # fresh retrievals feed the shared tier: memoized
                        # for semantic reuse, offered toward admission
                        with self._shared_lock:
                            for j, i in enumerate(miss):
                                tok = self._token(ws.pad_sids[i])
                                self.shared.memo_record(
                                    tok, ws.psi_np[i], ans.ids[j],
                                    ans.scores[j], float(radii[j]))
                                self.shared.offer(
                                    tok, ws.psi_np[i], float(radii[j]),
                                    ws.new_emb[i], ws.new_ids[i])
                except TimeoutError as e:
                    # total back-end failure: miss sessions fall back to
                    # their caches (or the stale memo); one with neither
                    # fails alone, like its sequential counterpart — not
                    # the whole wave
                    self._outage_fallback(ws, e)
                    if ws.failed[:wave].all():
                        raise
            return ws
        finally:
            ws.backend_s = time.perf_counter() - t0

    def _outage_fallback(self, ws: WaveState, e: BaseException) -> None:
        """Walk the degradation ladder for a wave whose back-end search
        was shed or failed entirely: warm-cache rows answer from their
        caches (fill_wave's query path), empty-cache rows try the L2
        memo *stale-while-error* (TTL and same-session gates waived;
        served docs warm L1 but the claim is never recorded, so nothing
        learns from stale data), and only rows with neither fail."""
        ws.degraded = True
        ws.outage = e
        failed = np.logical_and(ws.need, np.asarray(ws.sub.n_docs) == 0)
        if self.shared is not None and failed.any():
            with self._shared_lock:
                for i in np.nonzero(failed)[0]:
                    m = self.shared.memo_lookup(
                        self._token(ws.pad_sids[i]), ws.psi_np[i],
                        allow_stale=True)
                    if m is None:
                        continue
                    m_ids, _m_scores, _claim = m
                    ws.reuse[i] = True
                    ws.stale[i] = True
                    ws.tier[i] = "l2_reuse"
                    n = min(self.k_c, m_ids.shape[0])
                    ws.new_ids[i, :n] = m_ids[:n]
                    ws.new_emb[i, :n] = self.doc_embeddings[
                        np.maximum(m_ids[:n], 0)]
                    failed[i] = False        # rec_np stays False: no claim
                    self.telemetry.record_fault("stale_served")
        ws.failed = failed

    # -------------------------------------------------------- fill phase
    def fill_wave(self, ws: WaveState) -> list:
        """Phase 3: fused insert+query launch, L1 scatter, admission
        flush, and per-turn assembly.  Returns one entry per real session
        in input order: an ``EngineTurn``, or a ``TimeoutError`` instance
        for a session whose back-end failed entirely while its cache was
        still empty.
        """
        t0 = time.perf_counter()
        if self.prefetch_width and self.cluster is not None:
            # Topical prefetch: expand each fresh back-end answer with its
            # cluster's nearest-to-centroid docs (the prefetch_width extra
            # buffer columns), riding the same fused launch below.  With
            # the whole ball(centroid, d_w) cached, the triangle
            # inequality makes ball(psi, d_w - ||psi - c||) cached too, so
            # the recorded claim widens to max(r_a, d_w - ||psi - c||).
            # (Like the r_a claim itself, this assumes capacity headroom —
            # dropped inserts void claims; size L1 >= k_c + width.)
            for i in np.nonzero(ws.backend_ok)[0]:
                extra, bound = self.cluster.prefetch(
                    ws.psi_np[i], ws.new_ids[i, :self.k_c],
                    self.prefetch_width)
                if extra.size:
                    ws.new_ids[i, self.k_c:self.k_c + extra.size] = extra
                    ws.new_emb[i, self.k_c:self.k_c + extra.size] = \
                        self.doc_embeddings[extra]
                    self.prefetch_issued += int(extra.size)
                    self._prefetched[int(ws.pad_sids[i])].update(
                        extra.tolist())
                if ws.rec_np[i] and bound > ws.rad[i]:
                    ws.rad[i] = bound
        fill = np.logical_or(np.logical_or(ws.reuse, ws.l2hit),
                             ws.backend_ok)
        if fill.any():
            self.insert_traffic_docs += int((ws.new_ids[fill] >= 0).sum())
            # insert + answer query FUSED: one kernel launch closes the
            # wave (L1-only: launch 3 of 3, probe -> miss-search ->
            # insert+query; tiered: launch 4 of 4, after the L2 probe)
            (scores, _dists, ids, _slots), sub, dropped = \
                insert_query_batched(
                    ws.sub, self.cache.cfg, ws.psi, jnp.asarray(ws.rad),
                    jnp.asarray(ws.new_emb), jnp.asarray(ws.new_ids),
                    self.k, do=jnp.asarray(fill),
                    record=jnp.asarray(ws.rec_np), backend=self.backend)
            self.cache.total_dropped += int(np.asarray(dropped).sum())
        else:  # missless (or outage) wave: probe -> query
            (scores, _dists, ids, _slots), sub = query_batched(
                ws.sub, ws.psi, self.k, backend=self.backend)
        able = np.nonzero(~ws.failed[:ws.wave])[0]
        # write back only real, answerable rows (padded rows are shadows of
        # row 0; failed rows must stay exactly as they were, like a
        # sequential engine raising before its cache query)
        self.cache.scatter(ws.sids[able],
                           jax.tree_util.tree_map(lambda x: x[able], sub))
        if self.shared is not None:
            # end-of-wave: promote the wave's admitted answers into their
            # shards (deferred so admission never adds launches mid-wave)
            with self._shared_lock:
                self.shared.flush_admissions(backend=self.backend)

        resolved = time.perf_counter()
        insert_s = resolved - t0
        out: list = []
        for i, s in enumerate(ws.sids):
            if ws.failed[i]:
                self.telemetry.record_fault("failed_turns")
                out.append(TimeoutError(
                    f"session {int(s)}: back-end down and cache empty"
                    f" ({ws.outage})"))
                continue
            # drop (id -1, score -inf) sentinel slots of a short cache, the
            # same trim the sequential engine applies
            row_ids = np.asarray(ids[i])
            row_scores = np.asarray(scores[i])
            real = row_ids >= 0
            row_tier = str(ws.tier[i])
            pre = self._prefetched[int(s)]
            n_pre = (sum(1 for d in row_ids[real].tolist() if d in pre)
                     if pre else 0)
            if n_pre and row_tier != "backend":
                self.prefetch_warm_hits += n_pre
            spans = TurnSpans(
                queue_wait_s=max(ws.t_start - float(ws.admitted_at[i]), 0.0),
                probe_s=ws.probe_s, backend_s=ws.backend_s,
                insert_s=insert_s,
                total_s=resolved - float(ws.admitted_at[i]), tier=row_tier)
            # a degraded wave degrades its backend-tier rows AND any row
            # served stale-while-error (fresh tier hits stay first-class)
            turn = EngineTurn(ids=row_ids[real], scores=row_scores[real],
                              hit=row_tier != "backend",
                              degraded=bool(ws.degraded
                                            and (row_tier == "backend"
                                                 or ws.stale[i])),
                              latency_s=spans.total_s, tier=row_tier,
                              queue_wait_s=spans.queue_wait_s, spans=spans,
                              prefetch_hits=n_pre)
            if turn.degraded:
                self.telemetry.record_fault("degraded_turns")
            self.telemetry.record_turn(spans)
            self.turns[int(s)].append(turn)
            out.append(turn)
        return out

    def answer_batch(self, sessions, queries) -> list:
        """Answer one concurrent turn per listed session (a wave), inline:
        ``probe_wave -> backend_wave -> fill_wave``.  Raises only when
        *every* session in the wave is an empty-cache back-end failure.
        """
        ws = self.probe_wave(sessions, queries)
        self.backend_wave(ws)
        return self.fill_wave(ws)

    def hit_rate(self, session: Optional[int] = None) -> float:
        """Cache hit rate, excluding each session's compulsory first turn.

        With a session index: that session's rate (NaN for sessions of
        <= 1 turn, which have no eligible turns).  With no argument: the
        aggregate across ALL sessions' eligible turns — the engine-level
        number serve_bench reports, well-defined as long as any session
        has a second turn.
        """
        if session is not None:
            turns = self.turns[session]
            if len(turns) <= 1:
                return float("nan")
            return float(np.mean([t.hit for t in turns[1:]]))
        flags = [t.hit for turns in self.turns for t in turns[1:]]
        if not flags:
            return float("nan")
        return float(np.mean(flags))

    def tier_counts(self, skip_first: bool = True) -> dict:
        """Turns served per hierarchy tier (``l1`` / ``l2`` / ``l2_reuse``
        / ``backend``), excluding each session's compulsory first turn by
        default (matching ``hit_rate`` accounting)."""
        counts = {"l1": 0, "l2": 0, "l2_reuse": 0, "backend": 0}
        for turns in self.turns:
            for t in (turns[1:] if skip_first else turns):
                counts[t.tier] += 1
        return counts

    def prefetch_stats(self) -> dict:
        """Cluster-prefetch accounting: ``issued`` docs inserted via
        prefetch, ``warm_hits`` prefetched docs that later appeared in a
        cache-served result, ``insert_traffic_docs`` total docs offered to
        the L1 insert launch (the cache-traffic axis of the Pareto sweep),
        and the configured ``width``."""
        return {"issued": self.prefetch_issued,
                "warm_hits": self.prefetch_warm_hits,
                "insert_traffic_docs": self.insert_traffic_docs,
                "width": self.prefetch_width}


class SessionManager:
    """Asynchronous front door: session keys -> engine slots -> waves.

    ``submit(key, query)`` returns a Future[EngineTurn]; turns are
    admitted into continuously scheduled ``BatchedEngine`` waves by a
    ``ContinuousScheduler`` — an arrival joins the next wave the engine
    can take (no fixed window), wave sizes adapt to the EWMA'd arrival
    rate, and wave *t+1*'s cache probe overlaps wave *t*'s back-end
    search.  Two turns of the same session are never in flight together
    (the scheduler defers the later one), preserving arrival order.

    Knobs: ``min_slots``/``max_slots`` bound the adaptive wave-size limit,
    ``ewma_horizon_s`` sets the arrival-rate memory, ``target_p99_s``
    backs wave sizes off when the measured turn p99 overshoots, and
    ``window_s > 0`` recovers the deprecated fixed-window admission for
    A/B comparison (serve_bench's baseline mode).
    """

    def __init__(self, engine: BatchedEngine, *, window_s: float = 0.0,
                 max_batch: Optional[int] = None, min_slots: int = 1,
                 max_slots: Optional[int] = None,
                 adaptive: Optional[bool] = None, headroom: float = 1.5,
                 ewma_horizon_s: float = 1.0,
                 target_p99_s: Optional[float] = None,
                 overlap: bool = True):
        self.engine = engine
        self._slots: dict = {}
        self._free = list(range(engine.n_sessions - 1, -1, -1))
        self.scheduler = ContinuousScheduler(
            engine, min_wave=min_slots,
            max_wave=max_slots or max_batch or engine.n_sessions,
            window_s=window_s, adaptive=adaptive, headroom=headroom,
            ewma_horizon_s=ewma_horizon_s, target_p99_s=target_p99_s,
            overlap=overlap)

    @property
    def batcher(self) -> ContinuousScheduler:
        """Deprecated alias for ``scheduler`` (pre-ISSUE-8 name)."""
        return self.scheduler

    @property
    def telemetry(self) -> ServeTelemetry:
        return self.scheduler.telemetry

    def open(self, key) -> int:
        """Start a session for ``key``; returns its engine slot."""
        if key in self._slots:
            raise KeyError(f"session {key!r} already open")
        if not self._free:
            raise RuntimeError("no free session slots")
        slot = self._free.pop()
        self.engine.start_session(slot)
        self._slots[key] = slot
        return slot

    def close(self, key):
        """End a session and recycle its slot, draining only THIS key's
        pending turns first (so a turn already submitted for it cannot
        execute against the slot's next occupant).  Other sessions'
        queued and in-flight turns are untouched — closing a session no
        longer force-flushes the global wave."""
        if key not in self._slots:
            raise KeyError(f"unknown session key {key!r}")
        self.scheduler.drain_slot(self._slots[key])
        self._free.append(self._slots.pop(key))

    def shutdown(self):
        """Drain pending turns and stop the scheduler's worker thread.
        Idempotent; further ``submit`` calls raise.  Benchmarks and tests
        that spin up many managers must call this (or use the manager as a
        context manager) so worker threads don't leak across runs."""
        self.scheduler.close()

    def __enter__(self) -> "SessionManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.shutdown()
        return False

    @property
    def active_sessions(self) -> int:
        return len(self._slots)

    def submit(self, key, query):
        """Admit one turn; returns a Future resolved with its EngineTurn.
        The admission timestamp is stamped here, so the resolved turn's
        ``latency_s`` covers queue wait + wave execution."""
        return self.scheduler.submit(query, slot=self._slots[key])

    def flush(self):
        """Force everything queued now to execute (tests, shutdown)."""
        self.scheduler.flush()
