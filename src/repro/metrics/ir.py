"""Offline IR effectiveness metrics (paper Sec. 3.2): MAP/MRR/nDCG/P@k, coverage.

Evaluation is offline and tiny — plain numpy, matching trec_eval semantics:
graded qrels (grade > 0 == relevant for the binary metrics).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

__all__ = ["precision_at_k", "average_precision", "mrr", "ndcg_at_k",
           "coverage", "mean_metric"]

Qrels = Mapping[int, int]  # doc_id -> grade


def _rel(ranked: Sequence[int], qrels: Qrels) -> np.ndarray:
    return np.array([qrels.get(int(d), 0) for d in ranked], dtype=np.float64)


def precision_at_k(ranked: Sequence[int], qrels: Qrels, k: int) -> float:
    rel = _rel(ranked[:k], qrels) > 0
    return float(rel.sum() / k)


def average_precision(ranked: Sequence[int], qrels: Qrels, k: int = 200) -> float:
    """MAP@k with the standard trec_eval denominator: total #relevant docs."""
    n_rel = sum(1 for g in qrels.values() if g > 0)
    if n_rel == 0:
        return 0.0
    rel = _rel(ranked[:k], qrels) > 0
    cum = np.cumsum(rel)
    prec = cum / np.arange(1, len(rel) + 1)
    return float((prec * rel).sum() / n_rel)


def mrr(ranked: Sequence[int], qrels: Qrels, k: int = 200) -> float:
    rel = _rel(ranked[:k], qrels) > 0
    hits = np.nonzero(rel)[0]
    return float(1.0 / (hits[0] + 1)) if hits.size else 0.0


def ndcg_at_k(ranked: Sequence[int], qrels: Qrels, k: int = 3) -> float:
    gains = _rel(ranked[:k], qrels)
    discounts = 1.0 / np.log2(np.arange(2, len(gains) + 2))
    dcg = float((gains * discounts).sum())
    ideal = np.sort([g for g in qrels.values() if g > 0])[::-1][:k].astype(np.float64)
    if ideal.size == 0:
        return 0.0
    idcg = float((ideal * (1.0 / np.log2(np.arange(2, ideal.size + 2)))).sum())
    return dcg / idcg if idcg > 0 else 0.0


def coverage(cache_ids: Sequence[int], exact_ids: Sequence[int], k: int) -> float:
    """Eq. 5: |NN(C,psi,k) ∩ NN(M,psi,k)| / k."""
    return float(len(set(map(int, cache_ids[:k])) & set(map(int, exact_ids[:k]))) / k)


def mean_metric(fn, runs, qrels_by_q, **kw) -> float:
    """Average fn(ranked, qrels) over queries present in both runs and qrels."""
    vals = [fn(ranked, qrels_by_q[q], **kw) for q, ranked in runs.items()
            if q in qrels_by_q]
    return float(np.mean(vals)) if vals else float("nan")
