"""IR quality metrics (MRR, nDCG@k, AP, coverage) shared by the paper
tables and the benchmark gates."""

from repro.metrics.ir import (average_precision, coverage, mean_metric, mrr,
                              ndcg_at_k, precision_at_k)

__all__ = ["average_precision", "coverage", "mean_metric", "mrr",
           "ndcg_at_k", "precision_at_k"]
