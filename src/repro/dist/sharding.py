"""PartitionSpec derivation: parameters (``param_specs``) and activations
(``lm_activation_rules`` & friends).

``param_specs`` walks a parameter pytree (of arrays or ShapeDtypeStructs)
and assigns each leaf a full-rank PartitionSpec:

  * name-keyed rules first — vocab/item tables get Megatron-style vocab
    parallelism, MoE expert stacks get expert parallelism over "model";
  * a shape heuristic otherwise — the larger of the last two dims goes to
    "model" (column/row parallel), the other is FSDP-sharded over the data
    axes when it divides;
  * every assignment is divisibility-checked, small leaves replicate.

Specs are what ``launch/cells.py`` feeds to ``jax.jit`` in/out shardings and
what the optimizers mirror into their state (``Optimizer.state_spec``).

``lm_activation_rules`` produces the logical-name table consumed by
``dist.api.constrain`` for a transformer cell; per-name assignments degrade
to replication when head/vocab counts do not divide the "model" axis (the
rules must serve every assigned arch on every mesh).
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.api import data_axes, fit_spec

__all__ = ["param_specs", "lm_activation_rules", "gnn_activation_rules",
           "replicated_specs"]


def _tp(mesh: Mesh) -> int:
    return dict(mesh.shape).get("model", 1)


def _dp_prod(mesh: Mesh) -> int:
    size = 1
    for a in data_axes(mesh):
        size *= mesh.shape[a]
    return size


def _dp_entry(mesh: Mesh):
    """The data-axes spec entry: a single name, a tuple, or None."""
    dp = data_axes(mesh)
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else dp


def _key_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def replicated_specs(tree):
    """A same-structure tree of fully-replicated full-rank specs."""
    return jax.tree.map(lambda l: P(*((None,) * len(l.shape))), tree)


def param_specs(params_shapes, mesh: Mesh, *, min_shard_size: int = 2 ** 14):
    """Full-rank PartitionSpecs for a parameter tree on ``mesh``.

    Layer-stacked leaves (vmapped init => leading stack dim) keep the stack
    dim unsharded so slice-at-a-time optimizer updates stay local.
    """
    tp = _tp(mesh)
    dp = _dp_entry(mesh)
    dp_prod = _dp_prod(mesh)

    def divides(dim: int, size: int) -> bool:
        return size > 0 and dim % size == 0

    def heuristic(shape) -> P:
        ndim = len(shape)
        spec = [None] * ndim
        if ndim >= 2:
            last, prev = ndim - 1, ndim - 2
            cands = [d for d in (last, prev)
                     if divides(shape[d], tp) and shape[d] >= 2 * tp]
            if cands:
                model_dim = max(cands, key=lambda d: (shape[d], d))
                spec[model_dim] = "model"
                other = prev if model_dim == last else last
                if dp is not None and divides(shape[other], dp_prod) \
                        and shape[other] >= 2 * dp_prod:
                    spec[other] = dp
        return P(*spec)

    def by_name(names, shape) -> P:
        leaf = names[-1] if names else ""
        ndim = len(shape)
        if leaf in ("embed", "item_emb") and ndim == 2:
            # vocab-parallel rows (matches the vocab-sharded "logits" rule);
            # never feature-shard a gathered table — SPMD cannot partition
            # the token gather against a trailing-dim-sharded operand
            return P("model" if divides(shape[0], tp) else None, None)
        if leaf == "lm_head" and ndim == 2:
            return P(None, "model" if divides(shape[1], tp) else None)
        if leaf in ("tables", "linear") and ndim == 3:
            # (fields, vocab, dim): shard the vocab rows (or replicate)
            return P(None, "model" if divides(shape[1], tp) else None, None)
        if leaf in ("wi", "wo") and any("moe" in n for n in names) and ndim >= 3:
            # (stack?, experts, d, f): expert parallelism over "model"
            e_dim = ndim - 3
            if divides(shape[e_dim], tp):
                spec = [None] * ndim
                spec[e_dim] = "model"
                return P(*spec)
        if leaf == "router":
            return P(*((None,) * ndim))
        return heuristic(shape)

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        size = 1
        for s in shape:
            size *= s
        if len(shape) == 0 or size < min_shard_size:
            return P(*((None,) * len(shape)))
        spec = by_name(_key_names(path), shape)
        # belt & braces: every emitted assignment must divide
        return fit_spec(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def lm_activation_rules(mesh: Mesh, cfg, kind: str = "train") -> dict:
    """Logical-name -> PartitionSpec table for a transformer cell.

    ``cfg`` needs ``n_heads`` / ``n_kv_heads`` / ``attention`` (a duck-typed
    stub is fine — see launch/cells).  ``kind`` is the cell shape kind
    ("train" | "prefill" | "decode" | "long"); decode-like cells with
    non-TP-divisible KV heads shard the cache *sequence* axis instead, so
    decode attention lowers to a flash-decoding-style all-reduce merge.
    """
    tp = _tp(mesh)
    dp = _dp_entry(mesh)
    heads = "model" if getattr(cfg, "n_heads", 1) % tp == 0 else None
    kv = "model" if getattr(cfg, "n_kv_heads", 1) % tp == 0 else None
    vocab = getattr(cfg, "vocab_size", 0)
    logit = "model" if vocab and vocab % tp == 0 else None

    kv_cache = P(dp, None, kv, None)
    if kind in ("decode", "long") and kv is None:
        kv_cache = P(dp, "model", None, None)   # seq-sharded cache

    return {
        "act_bsd": P(dp, None, None),
        "act_bsf": P(dp, None, "model"),
        "act_bfd": P(dp, None, None),
        "act_bshd": P(dp, None, heads, None),
        "act_bskd": P(dp, None, kv, None),
        "attn_scores": P(dp, heads, None, None),
        "kv_cache": kv_cache,
        "mla_cache": P(dp, None, None),
        "mla_cache_r": P(dp, None, None),
        "logits": P(dp, None, logit),
        "moe_buf": P("model", None, None),
        "moe_hidden": P("model", None, None),
        "moe_out": P(dp, None),
    }


def gnn_activation_rules(mesh: Mesh) -> dict:
    """Edge/node tables shard over the whole mesh (segment-sum partials are
    psum'd by SPMD)."""
    every = tuple(mesh.axis_names)
    return {"edges": P(every, None), "nodes": P(every, None)}
