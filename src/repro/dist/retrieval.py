"""Distributed back-end retrieval: the sharded dense index of Fig. 2.

Three layers, smallest to largest deployment:

  * ``make_batched_scorer`` — a table-sharded MIPS top-k closure for use
    *inside* jitted serving cells (recsys retrieval_cand / serve shapes):
    candidate tables stay sharded where their params live, the (B, V) score
    matrix never materializes unsharded.
  * ``sharded_nn`` — exact k-NN with the corpus sharded across a device
    mesh: each device runs the same ``scan_topk`` contract over its slice
    under ``shard_map`` (the jnp streaming scan on the ref tier, the fused
    Pallas kernel on TPU — the SAME implementation single-device search
    uses), then the per-shard top-k are all-gathered and merged.  The merge
    is the device-level analogue of ``serve.router.ShardedRouter._merge``
    and is *bit-identical* in ranking to ``exact_nn`` (contiguous row
    sharding + stable top-k tie-breaking).
  * ``DeviceShard`` / ``make_device_shards`` — host-callable shard handles
    over device-resident corpus slices, signature-compatible with the
    callables ``ShardedRouter`` fronts, so the serving layer's hedging /
    degraded-answer machinery runs unchanged on real device shards.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import quant
from repro.core.metric_index import SearchResult, _as_result, scan_topk
from repro.dist.api import active_mesh
from repro.kernels import dispatch as kdispatch

__all__ = ["make_batched_scorer", "sharded_nn", "shard_corpus",
           "DeviceShard", "make_device_shards", "ShardTopK"]


# ------------------------------------------------------- batched scoring

def make_batched_scorer(mesh: Mesh, k: int, table_axes: Sequence[str] = ("model",),
                        batch_axes: Sequence[str] = ()):
    """Build ``scorer(queries, table, n_valid=None) -> (scores, ids)``.

    ``table`` (V, D) is constrained to shard its rows over ``table_axes``,
    ``queries`` (B, D) over ``batch_axes`` — SPMD then keeps the (B, V)
    score matrix sharded over both and lowers the top-k to per-shard top-k
    plus a merge collective.  ``n_valid`` masks trailing table rows (an
    unevenly-sized candidate set scored against a shard-divisible table).
    For use inside jitted cells; ids are row positions in ``table``.
    """
    t_entry = tuple(table_axes) or None
    b_entry = tuple(batch_axes) or None

    def scorer(queries: jax.Array, table: jax.Array,
               n_valid: Optional[int] = None):
        queries = jax.lax.with_sharding_constraint(
            queries, NamedSharding(mesh, P(b_entry, None)))
        table = jax.lax.with_sharding_constraint(
            table, NamedSharding(mesh, P(t_entry, None)))
        scores = queries @ table.T                              # (B, V)
        if n_valid is not None:
            col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            scores = jnp.where(col < n_valid, scores, -jnp.inf)
        return jax.lax.top_k(scores, min(k, table.shape[0]))

    return scorer


# ----------------------------------------------------- sharded exact k-NN

def _flat_mesh() -> Mesh:
    """A 1-axis mesh over every local device (the default retrieval mesh)."""
    return Mesh(np.asarray(jax.devices()), ("shard",))


def _resolve(mesh: Optional[Mesh], axes: Optional[Sequence[str]]):
    mesh = mesh if mesh is not None else (active_mesh() or _flat_mesh())
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    return mesh, axes, n_dev


def _slice_layout(n: int, n_dev: int, chunk: int):
    """(rows per device, effective chunk): equal, chunk-divisible slices."""
    per = -(-n // n_dev)
    chunk_eff = min(chunk, per)
    per = -(-per // chunk_eff) * chunk_eff
    return per, chunk_eff


def _pad_corpus(docs: jax.Array, doc_ids: jax.Array, rows: int,
                scale: Optional[jax.Array] = None):
    """Sentinel-pad (id -1, masked to -inf) to exactly ``rows`` rows."""
    pad = rows - docs.shape[0]
    if pad:
        docs = jnp.concatenate(
            [docs, jnp.zeros((pad, docs.shape[1]), docs.dtype)])
        doc_ids = jnp.concatenate(
            [doc_ids, jnp.full((pad,), -1, jnp.int32)])
        if scale is not None:
            scale = jnp.concatenate(
                [scale, jnp.ones((pad,), scale.dtype)])
    return docs, doc_ids, scale


def shard_corpus(docs, doc_ids, *, scale: Optional[jax.Array] = None,
                 mesh: Optional[Mesh] = None,
                 axes: Optional[Sequence[str]] = None, chunk: int = 4096):
    """Pad a corpus to equal per-device slices and commit it to the mesh.

    ``docs`` may be a quantized payload (bf16 / int8) with ``scale`` its
    per-document f32 score multiplier, which shards row-aligned with it.
    Returns (docs, doc_ids, scale, mesh, chunk_eff) with the rows already
    laid out P(axes) across devices, so repeated ``sharded_nn`` calls (a
    serving index) pay no per-query re-pad or host->mesh re-layout.
    """
    mesh, axes, n_dev = _resolve(mesh, axes)
    docs = jnp.asarray(docs)
    doc_ids = jnp.asarray(doc_ids, jnp.int32)
    per, chunk_eff = _slice_layout(docs.shape[0], n_dev, chunk)
    docs, doc_ids, scale = _pad_corpus(docs, doc_ids, per * n_dev, scale)
    entry = axes if len(axes) > 1 else axes[0]
    docs = jax.device_put(docs, NamedSharding(mesh, P(entry, None)))
    doc_ids = jax.device_put(doc_ids, NamedSharding(mesh, P(entry)))
    if scale is not None:
        scale = jax.device_put(scale, NamedSharding(mesh, P(entry)))
    return docs, doc_ids, scale, mesh, chunk_eff


@functools.lru_cache(maxsize=None)
def _sharded_search_fn(mesh: Mesh, axes: Tuple[str, ...], k: int, chunk: int,
                       backend: str, quantized: bool, int8_dot: bool):
    """jit(shard_map) factory, cached per (mesh, axes, k, chunk, backend,
    quantized, int8_dot).

    Per device: the shared ``scan_topk`` contract over the local corpus
    slice (jnp streaming scan or the fused Pallas kernel, per ``backend``;
    a quantized slice carries its per-document scale shard-aligned), then
    an all-gather of the (q, k) partials over the corpus axes and a local
    merge — every device ends with the identical global top-k (replicated
    out).
    """
    axis_entry = axes if len(axes) > 1 else axes[0]

    def merge(part_s, part_i):
        # shard order == row order (contiguous row sharding), so the
        # concatenated candidate list preserves global id order and the
        # stable top_k below breaks ties exactly like a global top_k.
        all_s = jax.lax.all_gather(part_s, axes, axis=1, tiled=True)
        all_i = jax.lax.all_gather(part_i, axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(all_s, k)
        return top_s, jnp.take_along_axis(all_i, pos, axis=1)

    if quantized:
        def local(docs, ids, scale, queries):
            return merge(*scan_topk(docs, ids, queries, k, chunk=chunk,
                                    backend=backend, scale=scale,
                                    int8_dot=int8_dot))
        in_specs = (P(axis_entry, None), P(axis_entry), P(axis_entry),
                    P(None, None))
    else:
        def local(docs, ids, queries):
            return merge(*scan_topk(docs, ids, queries, k, chunk=chunk,
                                    backend=backend, int8_dot=int8_dot))
        in_specs = (P(axis_entry, None), P(axis_entry), P(None, None))

    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return jax.jit(fn)


def sharded_nn(docs, doc_ids, queries, k: int, *, mesh: Optional[Mesh] = None,
               axes: Optional[Sequence[str]] = None, chunk: int = 4096,
               backend: Optional[str] = None,
               scale: Optional[jax.Array] = None,
               int8_dot: Optional[bool] = None) -> SearchResult:
    """Exact k-NN with the corpus sharded over ``mesh`` (all its axes by
    default; the active ``sharding_rules`` mesh, else one flat axis over
    every local device, when ``mesh`` is None).

    The corpus is padded with sentinel rows (id -1, masked to -inf) so each
    device gets an equal, chunk-divisible slice — a no-op when the corpus
    was pre-laid-out with ``shard_corpus`` (the serving-index fast path).
    ``backend`` picks the per-shard scan tier (``kernels.dispatch``; the
    default is compiled-kernel-on-TPU / jnp elsewhere).  ``docs`` may be a
    quantized payload (bf16 / int8) with ``scale`` its (n,) f32
    per-document score multiplier, sharded row-aligned with the corpus;
    ``int8_dot`` (None = the ``REPRO_INT8_DOT`` policy) switches int8
    shards to the native int8-MXU scoring rule, resolved here so every
    shard of one search scores identically.  Rankings are bit-identical to
    ``exact_nn`` on the unpadded corpus at fp32 (tolerance-bound rank
    equality at quantized dtypes).
    """
    mesh, axes, n_dev = _resolve(mesh, axes)
    docs = jnp.asarray(docs)
    doc_ids = jnp.asarray(doc_ids, jnp.int32)
    queries = jnp.asarray(queries)
    if queries.ndim == 1:
        queries = queries[None]

    n = docs.shape[0]
    per, chunk_eff = _slice_layout(n, n_dev, chunk)
    docs, doc_ids, scale = _pad_corpus(docs, doc_ids, per * n_dev, scale)

    fn = _sharded_search_fn(mesh, axes, int(min(k, n)), chunk_eff,
                            kdispatch.resolve(backend), scale is not None,
                            quant.resolve_int8_dot(int8_dot, docs.dtype))
    if scale is not None:
        scores, ids = fn(docs, doc_ids, scale, queries)
    else:
        scores, ids = fn(docs, doc_ids, queries)
    return _as_result(scores, ids)


# ------------------------------------------------- host-side shard handles

class ShardTopK(NamedTuple):
    """Host-side per-shard answer (duck-compatible with serve's ShardAnswer)."""
    scores: np.ndarray     # (B, k)
    ids: np.ndarray        # (B, k) global doc ids, -1 past the shard's corpus


class DeviceShard:
    """A host-callable index shard pinned to one device.

    ``shard(queries, k) -> ShardTopK`` — the exact callable signature
    ``serve.router.ShardedRouter`` fronts, so hedging, deadlines, and
    degraded merges apply unchanged.  Concurrent router threads run their
    shards on distinct devices in parallel.  The scan is the shared
    ``scan_topk`` contract (``backend`` pins a ``kernels.dispatch`` tier;
    ``dtype`` the corpus storage format — None follows the
    ``REPRO_CORPUS_DTYPE`` policy, and the slice is quantized once at
    construction).
    """

    def __init__(self, docs, doc_ids, device=None, chunk: int = 4096,
                 backend: Optional[str] = None, dtype: Optional[str] = None):
        docs = jnp.asarray(docs)
        doc_ids = jnp.asarray(doc_ids, jnp.int32)
        n = docs.shape[0]
        self.chunk = int(min(chunk, max(8, n)))
        self.dtype = quant.resolve_dtype(dtype)
        qc = quant.quantize(docs, self.dtype)
        docs, doc_ids, scale = _pad_corpus(qc.data, doc_ids,
                                           n + (-n) % self.chunk, qc.scale)
        self.device = device
        self.backend = kdispatch.resolve(backend)
        # the int8-MXU-dot policy is resolved once per shard, so a shard's
        # scoring rule never flips mid-deployment under an env change
        self.int8_dot = quant.resolve_int8_dot(None, self.docs_dtype())
        self.n_docs = n
        self.docs = jax.device_put(docs, device)
        self.doc_ids = jax.device_put(doc_ids, device)
        self.scale = (None if scale is None
                      else jax.device_put(scale, device))

    def docs_dtype(self):
        return quant.storage_dtype(self.dtype)

    def __call__(self, queries, k: int) -> ShardTopK:
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim == 1:
            q = q[None]
        if self.device is not None:
            q = jax.device_put(q, self.device)
        scores, ids = scan_topk(self.docs, self.doc_ids, q, int(k),
                                chunk=self.chunk, backend=self.backend,
                                scale=self.scale, int8_dot=self.int8_dot)
        return ShardTopK(np.asarray(scores), np.asarray(ids))


def make_device_shards(docs, doc_ids=None, *, devices=None,
                       chunk: int = 4096, dtype: Optional[str] = None) -> list:
    """Split a corpus into one ``DeviceShard`` per device (equal, padded
    slices so every shard shares a single jit trace)."""
    docs = jnp.asarray(docs)
    if doc_ids is None:
        doc_ids = jnp.arange(docs.shape[0], dtype=jnp.int32)
    doc_ids = jnp.asarray(doc_ids, jnp.int32)
    devices = list(devices if devices is not None else jax.devices())
    n = docs.shape[0]
    per = -(-n // len(devices))
    shards = []
    for i, dev in enumerate(devices):
        lo, hi = i * per, min((i + 1) * per, n)
        if lo >= n:
            break
        shards.append(DeviceShard(docs[lo:hi], doc_ids[lo:hi], device=dev,
                                  chunk=min(chunk, per), dtype=dtype))
    return shards
