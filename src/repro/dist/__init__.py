"""Sharding + distribution substrate.

``repro.dist`` is the single place where logical shardings become physical
ones:

  * :mod:`repro.dist.api`       — ``constrain`` (logical activation sharding),
    the ``sharding_rules`` context, ``active_mesh``, ``data_axes``.
  * :mod:`repro.dist.sharding`  — parameter/activation PartitionSpec
    derivation (``param_specs``, ``lm_activation_rules``).
  * :mod:`repro.dist.retrieval` — the distributed back-end index: sharded
    exact k-NN over a device mesh, batched table-sharded MIPS scoring, and
    host-callable device shard handles for the serving router.

Model code only ever names *logical* axes (``constrain(x, "act_bsd")``);
meshes and rules are bound by the launcher (``launch/cells.py``,
``launch/dryrun.py``) or by tests.  Without an active ``sharding_rules``
context every annotation is the identity, so single-device smoke paths run
the exact same model code.
"""

from repro.dist import api, sharding  # noqa: F401

# ``repro.dist.retrieval`` is imported on demand (``import repro.dist.retrieval``)
# rather than eagerly: it pulls in ``repro.core``, which model modules that
# only need ``constrain`` should not pay for at import time.

