"""Logical activation sharding: ``constrain`` + the ``sharding_rules`` context.

Model code annotates activations with *logical* names::

    x = constrain(x, "act_bsd")

and never mentions a mesh.  The launcher binds a mesh and a rule table
(``{logical name -> PartitionSpec}``) around tracing::

    with sharding_rules(mesh, rules):
        jax.jit(step, in_shardings=..., out_shardings=...).lower(*args)

Inside the context every ``constrain`` lowers to
``jax.lax.with_sharding_constraint``; outside it is the identity, so the
same model code runs unannotated on a single device (all smoke tests).

Rules are *advisory*: an axis assignment that does not divide the concrete
dimension (smoke configs run tiny shapes through the same code) is dropped
per-dimension rather than erroring — see ``fit_spec``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["constrain", "sharding_rules", "active_mesh", "active_rules",
           "data_axes", "fit_spec"]


class _Stack(threading.local):
    """Per-thread stack of (mesh, rules) contexts (router threads must not
    observe a context entered on the main thread mid-trace)."""

    def __init__(self):
        self.items = []


_CTX = _Stack()


@contextlib.contextmanager
def sharding_rules(mesh, rules: Mapping[str, P]):
    """Bind ``mesh`` + logical-name rules for ``constrain`` during tracing."""
    _CTX.items.append((mesh, dict(rules)))
    try:
        yield
    finally:
        _CTX.items.pop()


def active_mesh():
    """The mesh of the innermost ``sharding_rules`` context, or None."""
    return _CTX.items[-1][0] if _CTX.items else None


def active_rules() -> dict:
    """The rule table of the innermost context ({} when none is active)."""
    return dict(_CTX.items[-1][1]) if _CTX.items else {}


def data_axes(mesh) -> Tuple[str, ...]:
    """Every mesh axis that is not the tensor-parallel "model" axis — the
    axes batch-like dimensions shard over (("pod", "data") on the multi-pod
    mesh, ("data",) otherwise)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _entry_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    size = 1
    for a in entry:
        size *= mesh.shape[a]
    return size


def fit_spec(spec: P, shape: Tuple[int, ...], mesh) -> Optional[P]:
    """Clamp a logical PartitionSpec to a concrete array shape.

    Missing trailing dims are padded with None; an axis assignment whose
    mesh-axis product does not divide the dimension is dropped (replicated).
    Returns None when the spec has more entries than the array has dims —
    the caller should skip the constraint entirely.
    """
    entries = tuple(spec)
    if len(entries) > len(shape):
        return None
    entries = entries + (None,) * (len(shape) - len(entries))
    fitted = tuple(e if dim % _entry_size(mesh, e) == 0 else None
                   for dim, e in zip(shape, entries))
    return P(*fitted)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Apply the active logical sharding rule ``name`` to ``x``.

    Identity when no ``sharding_rules`` context is active, the name has no
    rule, or the rule cannot fit the array's shape.
    """
    if not _CTX.items:
        return x
    mesh, rules = _CTX.items[-1]
    spec = rules.get(name)
    if spec is None:
        return x
    spec = fit_spec(spec, x.shape, mesh)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
