"""Checkpointing: async-friendly save/restore of jax pytrees (caches,
optimizer state, serving state) with a manifest-driven manager."""

from repro.checkpoint.manager import CheckpointManager, restore_tree, save_tree

__all__ = ["CheckpointManager", "restore_tree", "save_tree"]
