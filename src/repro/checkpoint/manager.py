"""Fault-tolerant checkpointing (orbax is not available offline).

Design points for 1000+-node runs:
  * **Atomic**: writes go to ``<dir>/tmp.<step>`` then a single ``rename`` —
    a killed job never leaves a half-readable checkpoint.
  * **Integrity**: per-leaf CRC32 in the manifest; restore verifies.
  * **Async**: ``save(..., blocking=False)`` copies to host then writes in a
    background thread — training continues during I/O.
  * **Elastic restore**: leaves are stored UNSHARDED (gathered); restore
    takes target shardings and ``device_put``s into ANY mesh — restart on a
    different device count after a node failure just works.  (At true 1e12-
    param scale you'd write per-shard files; the manifest format has a
    ``shards`` field reserved for that.)
  * **Retention**: keep-last-N garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save_tree(tree: Any, directory: str, step: int, *, keep: int = 3,
              blocking: bool = True) -> threading.Thread | None:
    """Write ``tree`` to ``directory/step_<step>`` atomically."""
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    # gather to host before any I/O (donation-safe, async-friendly)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        tmp = os.path.join(directory, f"tmp.{step}")
        final = os.path.join(directory, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "shards": None}
        for i, (key, arr) in enumerate(sorted(host.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(directory: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_tree(template: Any, directory: str, step: Optional[int] = None,
                 shardings: Any = None) -> Any:
    """Restore into the structure of ``template``; reshard onto ``shardings``
    (a pytree of jax.sharding.Sharding) if given — the elastic-restart path."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_t, treedef = _flatten(template)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, leaf in flat_t.items():
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc"]:
            raise IOError(f"checkpoint corruption in leaf {key!r} "
                          f"(crc {crc} != {meta['crc']})")
        if key in flat_s:
            out[key] = jax.device_put(arr, flat_s[key])
        else:
            out[key] = jax.numpy.asarray(arr, dtype=leaf.dtype)
    # rebuild in template order
    leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    ordered = []
    for p, _l in leaves:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


class CheckpointManager:
    """Step-driven convenience wrapper with async save and auto-resume."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory, self.interval, self.keep = directory, interval, keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False):
        if not force and (step % self.interval):
            return
        self.wait()
        self._pending = save_tree(tree, self.directory, step, keep=self.keep,
                                  blocking=False)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_or(self, template: Any, shardings: Any = None):
        """(tree, step) from the latest checkpoint, or (template, 0)."""
        step = latest_step(self.directory)
        if step is None:
            return template, 0
        return restore_tree(template, self.directory, step, shardings), step
