"""Tier-agnostic metric-cache ops: the contract BOTH cache tiers share.

Extracted from ``repro.core.cache`` (ISSUE 7) so the probe path is no
longer monolithic: the per-session L1 tier (``cache.MetricCache`` /
``cache.BatchedMetricCache``) and the cross-session shared L2 tier
(``repro.core.shared.SharedTier``) are both thin owners of the SAME
functional ops over the SAME tile-aligned ``CacheState`` — an L2 shard is
just one more stacked-state row, so the fused Pallas wave kernels
(``kernels.cache_probe`` / ``kernels.cache_wave``) serve both tiers with
no new kernel contract.

State layout (all pre-allocated; ``-1`` ids / ``-inf`` radii mark empty
slots).  The leaves are allocated at the PHYSICAL extents (``Cp`` =
``cfg.phys_capacity``, ``Dp`` = ``cfg.phys_dim``, ``Qp`` =
``cfg.phys_max_queries`` — capacity rounded to the wave-kernel tile
multiple, dim to the lane multiple, the ring to the sublane multiple; see
``repro.core.layout``) so every kernel launch is zero-copy; the ops mask
on the *logical* extents in ``CacheConfig`` and padded slots permanently
hold the empty-slot sentinels:
  doc_emb   (Cp, Dp)          cached transformed document embeddings, stored
                              in ``cfg.store_dtype`` (fp32 / bf16 / int8 —
                              ``repro.core.quant`` formats)
  doc_ids   (Cp,)             global document ids, -1 = empty
  doc_stamp (Cp,)             last-use step (for the beyond-paper LRU policy)
  q_emb     (Qp, Dp)          embeddings of queries answered by the back-end
                              (same storage format as doc_emb)
  q_radius  (Qp,)             r_a — distance of the k_c-th doc retrieved
  n_docs, step                scalars
  n_queries                   total queries ever recorded (monotone); the
                              query records live in a ring over the LOGICAL
                              ``max_queries`` slots, so the number of
                              *valid* records is min(n_queries, max_queries)
  doc_scale (Cp,)             f32 per-document score multipliers (all ones
                              unless store_dtype == "int8")
  q_scale   (Qp,)             f32 per-record score multipliers, ditto

Quantized storage rides the same dequantization rule as the corpus scan
(``quant.scale_scores``): probe / query / insert cast the payload to f32,
run the arithmetic in f32, and apply the per-row scale score-side — so at
store_dtype "fp32" the scales are exactly 1.0 and every op is bit-identical
to the unquantized cache, while bf16 / int8 caches hold 2x / 4x the
documents per byte of client memory (paper RQ1.C).

Paper-faithful behaviour: no eviction (overflowing inserts are an error in
strict mode / dropped otherwise); the LowQuality test of Eq. 3/4 decides
hits.  Beyond-paper extensions (flagged, off by default): LRU eviction and
distance-based ("ball") eviction so unbounded conversations stay bounded.

Batched multi-axis variants: every op also ships in a batched variant
(``probe_batched`` / ``query_batched`` / ``insert_batched`` / the fused
``insert_query_batched``) over a ``CacheState`` whose leaves carry a
leading axis (``init_batched_cache``) — SESSIONS for the L1 tier, SHARDS
for the L2 tier.  The ref tier of each is a ``vmap`` of the scalar op —
per row it computes exactly the same result — while the kernel tiers run
the whole wave as ONE fused Pallas launch, bit-identical per row to the
vmap path; per-row ``do`` / ``record`` masks make a wave of concurrent
turns with mixed hits and misses update only the rows that actually
missed.

The write-position logic (``insert_positions`` — dedup, append, and the
eviction policies) lives HERE, once: the L1 batched scatter and the L2
admission path both call it, so L2 admission semantics can never drift
from L1 eviction semantics.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as emb
from repro.core import layout
from repro.core import quant
from repro.kernels import dispatch as kdispatch

__all__ = ["CacheState", "CacheConfig", "ProbeResult", "init_cache",
           "init_batched_cache", "reset_sessions", "probe", "query",
           "insert", "probe_batched", "query_batched", "insert_batched",
           "insert_query_batched", "pad_features", "store_rows",
           "dedup_mask", "evicting_positions", "insert_positions",
           "validate_state"]


class CacheState(NamedTuple):
    doc_emb: jax.Array
    doc_ids: jax.Array
    doc_stamp: jax.Array
    q_emb: jax.Array
    q_radius: jax.Array
    n_docs: jax.Array
    n_queries: jax.Array
    step: jax.Array
    doc_scale: jax.Array
    q_scale: jax.Array


class CacheConfig(NamedTuple):
    capacity: int              # logical doc-slot count (mask extent)
    dim: int                   # logical feature width
    max_queries: int = 64      # logical query-record ring length
    epsilon: float = 0.04      # the paper's tuned default (Fig. 4)
    dedup: bool = True
    eviction: str = "none"     # "none" (paper) | "lru" | "ball" (beyond-paper)
    dtype: object = jnp.float32
    store_dtype: str = "fp32"  # quant.DTYPES embedding storage format

    # Physical allocation extents (derived, so the config stays a hashable
    # static-jit argument): the CacheState leaves are allocated at these at
    # init and every kernel launch rides them unchanged — zero-copy.
    @property
    def phys_capacity(self) -> int:
        return layout.phys_capacity(self.capacity)

    @property
    def phys_dim(self) -> int:
        return layout.phys_dim(self.dim)

    @property
    def phys_max_queries(self) -> int:
        return layout.phys_queries(self.max_queries)


def init_cache(cfg: CacheConfig) -> CacheState:
    """Allocate one cache row at the PHYSICAL extents.

    Padded doc columns / ring slots are written with their empty-slot
    sentinels exactly once, here: id -1, scale 1.0, radius -inf, stamp 0,
    zero payload.  Every op masks on the logical extents (or relies on
    those sentinels), and dropped insert positions route past
    ``phys_capacity``, so no launch ever rewrites a padded slot — LRU
    stamps of padded columns stay 0 forever (regression-tested).
    """
    store = quant.storage_dtype(cfg.store_dtype)
    cp, dp, qp = cfg.phys_capacity, cfg.phys_dim, cfg.phys_max_queries
    return CacheState(
        doc_emb=jnp.zeros((cp, dp), store),
        doc_ids=jnp.full((cp,), -1, jnp.int32),
        doc_stamp=jnp.zeros((cp,), jnp.int32),
        q_emb=jnp.zeros((qp, dp), store),
        q_radius=jnp.full((qp,), -jnp.inf, cfg.dtype),
        n_docs=jnp.zeros((), jnp.int32),
        n_queries=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        doc_scale=jnp.ones((cp,), jnp.float32),
        q_scale=jnp.ones((qp,), jnp.float32),
    )


def pad_features(x: jax.Array, width: int) -> jax.Array:
    """Zero-pad the trailing feature axis to the state's physical width —
    a per-wave O(rows * dim) copy, never O(capacity).  No-op (and no
    traced pad) when already aligned."""
    short = width - x.shape[-1]
    if short == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, short)])


def store_rows(x: jax.Array, store_dtype: str):
    """Quantize rows into the cache storage format; scales always an array
    (ones when the format carries none), so CacheState leaves are uniform
    across dtypes."""
    qc = quant.quantize(x, store_dtype)
    if qc.scale is None:
        return qc.data, jnp.ones(x.shape[:-1], jnp.float32)
    return qc.data, qc.scale


class ProbeResult(NamedTuple):
    hit: jax.Array        # bool — r_hat >= epsilon for some cached query
    r_hat: jax.Array      # max over cached queries of (r_a - delta(psi_a, psi))
    nearest_q: jax.Array  # arg of that max (int32), -1 if cache has no queries


@functools.partial(jax.jit, static_argnames=("max_queries",))
def probe(state: CacheState, psi: jax.Array, epsilon: jax.Array | float,
          max_queries: int | None = None) -> ProbeResult:
    """The LowQuality test (Eq. 3/4). Cost: O(n_queries * dim) — a few us.

    Returns hit=False when the cache holds no queries (compulsory miss).
    ``max_queries`` is the LOGICAL ring length from ``CacheConfig``; ring
    slots past it are allocation padding and masked out.  When None (a
    caller without the config) the padded slots' permanent -inf radius
    sentinels keep them out of the argmax anyway.
    """
    n_slots = state.q_emb.shape[0]
    mq = n_slots if max_queries is None else max_queries
    idx = jnp.arange(n_slots)
    valid = jnp.logical_and(idx < state.n_queries, idx < mq)
    psi_p = pad_features(psi, state.q_emb.shape[-1])
    scores = quant.scale_scores(
        state.q_emb.astype(jnp.float32) @ psi_p, state.q_scale)
    dist = emb.distance_from_scores(scores)                      # (Qp,)
    r_hat = jnp.where(valid, state.q_radius - dist, -jnp.inf)
    best = jnp.argmax(r_hat)
    best_r = r_hat[best]
    hit = jnp.logical_and(state.n_queries > 0, best_r >= epsilon)
    return ProbeResult(hit, best_r, jnp.where(state.n_queries > 0, best, -1))


@functools.partial(jax.jit, static_argnames=("k",))
def query(state: CacheState, psi: jax.Array, k: int):
    """NN(C, psi, k): top-k cached docs. Returns (scores, distances, ids, slots).

    A cache holding fewer than k docs pads the answer with (id -1, score
    -inf) sentinel slots; callers must drop those rows before ranking-metric
    or result use (``serve.engine`` does).  The scan runs over the physical
    columns; padded columns carry id -1 so they score -inf, and the stable
    top-k (ascending empty slots) can never reach them while k <= the
    logical capacity.
    """
    psi_p = pad_features(psi, state.doc_emb.shape[-1])
    scores = quant.scale_scores(
        state.doc_emb.astype(jnp.float32) @ psi_p, state.doc_scale)  # (Cp,)
    scores = jnp.where(state.doc_ids >= 0, scores, -jnp.inf)
    top_s, slots = jax.lax.top_k(scores, k)
    ids = state.doc_ids[slots]
    # touch LRU stamps of returned docs — real ones only: refreshing the
    # stamp of an empty sentinel slot would make LRU eviction prefer
    # evicting live documents over reusing the untouched empty slot
    touch = jnp.where(ids >= 0, slots, state.doc_stamp.shape[0])
    new_stamp = state.doc_stamp.at[touch].set(state.step, mode="drop")
    state = state._replace(doc_stamp=new_stamp, step=state.step + 1)
    return (top_s, emb.distance_from_scores(top_s), ids, slots), state


def dedup_mask(new_ids: jax.Array, existing_ids: jax.Array) -> jax.Array:
    """True for the first occurrence of each id not already cached."""
    in_cache = (new_ids[:, None] == existing_ids[None, :]).any(axis=1)
    kc = new_ids.shape[0]
    ii, jj = jnp.triu_indices(kc, k=1)  # j > i pairs
    dup_later = jnp.zeros((kc,), bool).at[jj].max(new_ids[jj] == new_ids[ii])
    return jnp.logical_and(~in_cache, ~dup_later)


def evicting_positions(state: CacheState, capacity: int, keep: jax.Array,
                       evict_key: jax.Array, evictable: jax.Array,
                       drop: int):
    """Write positions for kept docs under an eviction policy.

    Appends fill the empty tail ([n_docs, capacity)); once the tail is
    exhausted, the remaining kept docs overwrite ``evictable`` slots in
    ascending ``evict_key`` order.  Non-evictable slots (empty ones, and
    occupied slots protected by the caller) rank last and are out of reach
    of the placeable range, so an append target can never double as an
    eviction target of the same call — the write sets are disjoint by
    construction.  Kept docs beyond what appends + evictions can place are
    dropped and counted, never collapsed onto one slot.

    ``capacity`` is the LOGICAL capacity (occupied slots only ever live in
    [0, capacity)); ``drop`` is the drop sentinel, the PHYSICAL capacity —
    a dropped doc must route past the allocation padding, because a padded
    column is a real column of a kernel launch and a doc written there
    would leak into the query scan as a live id.
    """
    rank = jnp.cumsum(keep) - 1                       # dense rank among kept
    append_pos = state.n_docs + rank
    evict_order = jnp.argsort(jnp.where(evictable, evict_key, jnp.inf))
    evict_rank = rank - (capacity - state.n_docs)     # 0-based among evictions
    evict_pos = evict_order[jnp.clip(evict_rank, 0, capacity - 1)]
    pos = jnp.where(append_pos < capacity, append_pos, evict_pos)
    placeable = evict_rank < evictable.sum()          # appends are < 0 here
    pos = jnp.where(jnp.logical_and(keep, placeable), pos, drop)
    dropped = jnp.logical_and(keep, ~placeable).sum().astype(jnp.int32)
    return pos, dropped


def insert_positions(state: CacheState, cfg: CacheConfig, psi: jax.Array,
                     new_ids: jax.Array):
    """Write positions for one insert batch: (keep, pos, dropped, new_n).

    THE position logic of the scalar ``insert`` — dedup, append, and the
    eviction policies — shared by the kernel-tier batched scatter
    (``kernels.cache_wave``) AND the L2 shared-tier admission path, so all
    of them stay bit-identical to the scalar path by construction.
    ``pos[j] == cfg.phys_capacity`` marks a dropped (or non-kept)
    document: the drop sentinel routes past the PHYSICAL capacity so it
    can neither land in a real column nor in an allocation-padding column
    of the pre-padded state.
    """
    kc = new_ids.shape[0]
    drop = cfg.phys_capacity
    keep = dedup_mask(new_ids, state.doc_ids) if cfg.dedup else jnp.ones((kc,), bool)
    keep = jnp.logical_and(keep, new_ids >= 0)

    if cfg.eviction in ("lru", "ball"):
        # Slots holding ids that this batch re-retrieved are part of the
        # (psi, r_a) coverage claim being recorded right now (dedup keeps
        # them out of the batch precisely because they are already cached);
        # evicting one in the same call would break the claim.
        occupied = state.doc_ids >= 0
        in_batch = (state.doc_ids[:, None] == new_ids[None, :]).any(axis=1)
        evictable = jnp.logical_and(occupied, ~in_batch)
        if cfg.eviction == "lru":
            # Beyond-paper: overflow overwrites the stalest occupied slots.
            key = state.doc_stamp.astype(state.q_radius.dtype)
        else:
            # Beyond-paper: overflow evicts docs farthest from the query.
            psi_p = pad_features(psi, state.doc_emb.shape[-1])
            key = -emb.distance_from_scores(quant.scale_scores(
                state.doc_emb.astype(jnp.float32) @ psi_p, state.doc_scale))
        pos, dropped = evicting_positions(state, cfg.capacity, keep, key,
                                          evictable, drop)
    else:  # paper-faithful: append, drop overflow (and report it)
        append_pos = state.n_docs + jnp.cumsum(keep) - 1
        fits = append_pos < cfg.capacity
        pos = jnp.where(jnp.logical_and(keep, fits), append_pos, drop)
        dropped = jnp.logical_and(keep, ~fits).sum().astype(jnp.int32)
    new_n = jnp.minimum(state.n_docs + keep.sum(), cfg.capacity)
    return keep, pos, dropped, new_n


@functools.partial(jax.jit, static_argnames=("cfg",))
def insert(state: CacheState, cfg: CacheConfig, psi: jax.Array, radius: jax.Array,
           new_emb: jax.Array, new_ids: jax.Array,
           record: jax.Array | bool = True) -> tuple[CacheState, jax.Array]:
    """Insert the k_c back-end results for a missed query ``psi``.

    Records (psi, r_a) for future LowQuality probes — unless ``record`` is
    False (degraded back-end answers carry an inflated r_a that would poison
    the cache with false coverage claims; the docs are still worth keeping).
    Then appends the new document embeddings (deduplicated by id when
    cfg.dedup; ids < 0 are sentinel padding and never inserted).  Returns
    (new_state, n_dropped) where n_dropped counts docs that did not fit
    (always 0 under the paper's sizing assumption; eviction policies only
    drop when a single batch exceeds the whole capacity).
    """
    _keep, pos, dropped, new_n = insert_positions(state, cfg, psi, new_ids)

    # embeddings enter the cache in the storage format: quantize the LOGICAL
    # rows (identity at fp32; int8 scales come from the real features), then
    # zero-pad to the physical width — the zero pad equals the init pad in
    # every storage format — and scatter payload + per-row scale together
    emb_q, emb_scale = store_rows(new_emb, cfg.store_dtype)
    emb_q = pad_features(emb_q, state.doc_emb.shape[-1])
    doc_emb = state.doc_emb.at[pos].set(emb_q, mode="drop")
    doc_scale = state.doc_scale.at[pos].set(emb_scale, mode="drop")
    doc_ids = state.doc_ids.at[pos].set(new_ids, mode="drop")
    doc_stamp = state.doc_stamp.at[pos].set(state.step, mode="drop")

    # query records live in a ring over the LOGICAL max_queries slots:
    # slot = total-count mod max_queries, so a full cache overwrites the
    # *oldest* record, not the most recent one — and the padded ring slots
    # past cfg.max_queries are never written
    rec = jnp.asarray(record, bool)
    qslot = jnp.mod(state.n_queries, cfg.max_queries)
    psi_q, psi_scale = store_rows(psi, cfg.store_dtype)
    psi_q = pad_features(psi_q, state.q_emb.shape[-1])
    q_emb = state.q_emb.at[qslot].set(
        jnp.where(rec, psi_q, state.q_emb[qslot]))
    q_scale = state.q_scale.at[qslot].set(
        jnp.where(rec, psi_scale, state.q_scale[qslot]))
    q_radius = state.q_radius.at[qslot].set(
        jnp.where(rec, radius, state.q_radius[qslot]))

    new_state = CacheState(
        doc_emb=doc_emb, doc_ids=doc_ids, doc_stamp=doc_stamp,
        q_emb=q_emb, q_radius=q_radius,
        n_docs=new_n.astype(jnp.int32),
        n_queries=(state.n_queries + rec.astype(jnp.int32)),
        step=state.step + 1,
        doc_scale=doc_scale, q_scale=q_scale,
    )
    return new_state, dropped


# --------------------------------------------------------------------------
# Batched variants: one stacked CacheState for S concurrent rows — L1
# sessions or L2 shards; the ops are tier-agnostic.  The ref tier of each
# op is a vmap of the scalar op, so per row the arithmetic — matmuls,
# argsorts, scatters — is the same program and the results match the
# scalar path exactly.  The kernel tiers run each op as ONE fused Pallas
# launch over the stacked state (``kernels.cache_probe`` for the probe,
# ``kernels.cache_wave`` for query/insert — and the fused
# ``insert_query_batched`` collapses the wave tail into a single launch),
# reusing the scalar ops' jnp position/ring logic so they stay
# bit-identical per row.  ``do``/``record`` masks make a mixed hit/miss
# wave update only the rows that missed (hit rows keep their state
# bitwise, LRU stamps included).
# --------------------------------------------------------------------------

def init_batched_cache(cfg: CacheConfig, n_sessions: int) -> CacheState:
    """A CacheState whose every leaf carries a leading (n_sessions,) axis."""
    one = init_cache(cfg)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_sessions,) + x.shape), one)


def reset_sessions(state: CacheState, cfg: CacheConfig,
                   mask: jax.Array) -> CacheState:
    """Re-initialize the rows where ``mask`` is True; others untouched."""
    fresh = init_batched_cache(cfg, mask.shape[0])
    return jax.tree_util.tree_map(
        lambda f, s: jnp.where(mask.reshape(mask.shape + (1,) * (s.ndim - 1)),
                               f, s), fresh, state)


@functools.partial(jax.jit, static_argnames=("backend", "max_queries"))
def probe_batched(state: CacheState, psi: jax.Array,
                  epsilon: jax.Array | float,
                  backend: str | None = None,
                  max_queries: int | None = None) -> ProbeResult:
    """One LowQuality test per row: psi is (S, dim).

    Dispatches on the kernel backend tier (``repro.kernels.dispatch``):
    the ref tier is a vmap of the scalar ``probe``; interpret/compiled run
    the whole wave as ONE fused Pallas launch over the stacked state
    (``cache_probe_batched``), ring-buffer validity included.  Both tiers
    agree bitwise on hit/nearest_q and to float tolerance on r_hat.
    ``max_queries`` is the LOGICAL ring length from ``CacheConfig`` (the
    ring of a pre-padded state is longer; its padded slots hold -inf
    radius sentinels, so omitting it stays correct, just unmasked).
    """
    be = kdispatch.resolve(backend)
    if be == "ref":
        one = functools.partial(probe, max_queries=max_queries)
        return ProbeResult(*jax.vmap(one, in_axes=(0, 0, None))(
            state, psi, epsilon))
    from repro.kernels.cache_probe.ops import cache_probe_batched
    hit, r_hat, idx = cache_probe_batched(
        state.q_emb, psi, state.q_radius, state.n_queries, epsilon,
        q_scale=state.q_scale, max_queries=max_queries,
        interpret=kdispatch.interpret_flag(be))
    return ProbeResult(hit, r_hat, idx)


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def query_batched(state: CacheState, psi: jax.Array, k: int,
                  backend: str | None = None):
    """Per-row top-k over (S,)-stacked caches.

    The ref tier is a vmap of the scalar ``query``; the kernel tiers run
    the whole wave as ONE fused Pallas launch (``kernels.cache_wave``) —
    scores, ids, *and* slot ordering (stable top-k, empty slots ascending)
    match the ref tier, and the LRU-stamp touch / step bump applied here
    are the scalar op's exact jnp updates.
    """
    be = kdispatch.resolve(backend)
    if be == "ref":
        return jax.vmap(query, in_axes=(0, 0, None))(state, psi, k)
    from repro.kernels.cache_wave import ops as wave_ops
    vals, ids, slots = wave_ops.wave_query_topk(
        state.doc_emb, state.doc_ids, state.doc_scale, psi, k,
        interpret=kdispatch.interpret_flag(be))
    new_state = _apply_query_touch(state, ids, slots)
    return (vals, emb.distance_from_scores(vals), ids, slots), new_state


def _apply_query_touch(state: CacheState, ids: jax.Array,
                       slots: jax.Array) -> CacheState:
    """The scalar ``query``'s state update after a kernel-tier wave top-k:
    refresh the LRU stamps of the returned REAL docs (empty-slot answers
    route to the capacity drop-sentinel) at the current step, then bump
    the step — shared by ``query_batched`` and ``insert_query_batched`` so
    the touch invariant lives in one place."""
    capacity = state.doc_stamp.shape[1]
    touch = jnp.where(ids >= 0, slots, capacity)
    new_stamp = jax.vmap(
        lambda st, tch, sv: st.at[tch].set(sv, mode="drop"))(
            state.doc_stamp, touch, state.step)
    return state._replace(doc_stamp=new_stamp, step=state.step + 1)


def _gated_batch(new_ids, do, record):
    n = new_ids.shape[0]
    do = jnp.ones((n,), bool) if do is None else jnp.asarray(do, bool)
    record = do if record is None else jnp.asarray(record, bool)
    return do, record


def _insert_batched_ref(state, cfg, psi, radius, new_emb, new_ids, do, record):
    def _one(s, p, r, e, i, d, rec):
        new_s, dropped = insert(s, cfg, p, r, e, i, rec)
        merged = jax.tree_util.tree_map(
            lambda a, b: jnp.where(d, a, b), new_s, s)
        return merged, jnp.where(d, dropped, 0)

    return jax.vmap(_one)(state, psi, radius, new_emb, new_ids, do, record)


def _insert_batched_kernel(state, cfg, psi, radius, new_emb, new_ids, do,
                           record, interpret, query_psi=None, k=None):
    """Kernel-tier batched insert (optionally fused with the wave query).

    Positions/ring slots come from the scalar ops' exact jnp logic
    (``insert_positions``, vmapped), gated per row by ``do`` — a masked
    row's positions all point at the drop sentinel, so its payload, ids,
    and LRU stamps pass through the scatter bit-identically.  The kernel
    does the heavy part: one pass over the stacked cache payload,
    scattering the k_c batch and (when ``query_psi`` is given) scoring the
    freshly blended tiles for the post-insert top-k.
    """
    from repro.kernels.cache_wave import ops as wave_ops
    _keep, pos, dropped, new_n = jax.vmap(
        lambda s, p, i: insert_positions(s, cfg, p, i))(state, psi, new_ids)
    pos = jnp.where(do[:, None], pos, cfg.phys_capacity)
    dropped = jnp.where(do, dropped, 0)
    rec_g = jnp.logical_and(do, record)
    emb_q, emb_scale = store_rows(new_emb, cfg.store_dtype)
    psi_q, psi_scale = store_rows(psi, cfg.store_dtype)
    qslot = jnp.mod(state.n_queries, cfg.max_queries)
    args = (state.doc_emb, state.doc_ids, state.doc_stamp, state.doc_scale,
            state.q_emb, state.q_radius, state.q_scale,
            emb_q, emb_scale, new_ids, pos, psi_q, psi_scale,
            jnp.asarray(radius, jnp.float32), rec_g, qslot, state.step)
    if query_psi is None:
        outs, q_out = wave_ops.wave_insert_scatter(
            *args, interpret=interpret), None
    else:
        outs, q_out = wave_ops.wave_insert_query(
            *args, psi=query_psi, k=k, interpret=interpret)
    demb, dids, dstamp, dscale, qemb, qrad, qsc = outs
    new_state = CacheState(
        doc_emb=demb, doc_ids=dids, doc_stamp=dstamp,
        q_emb=qemb, q_radius=qrad.astype(state.q_radius.dtype),
        n_docs=jnp.where(do, new_n, state.n_docs).astype(jnp.int32),
        n_queries=state.n_queries + rec_g.astype(jnp.int32),
        step=jnp.where(do, state.step + 1, state.step),
        doc_scale=dscale, q_scale=qsc,
    )
    return new_state, dropped.astype(jnp.int32), q_out


@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def insert_batched(state: CacheState, cfg: CacheConfig, psi: jax.Array,
                   radius: jax.Array, new_emb: jax.Array, new_ids: jax.Array,
                   do: jax.Array | None = None,
                   record: jax.Array | None = None,
                   backend: str | None = None):
    """Row-batched ``insert`` with per-row gating.

    psi (S, dim), radius (S,), new_emb (S, kc, dim), new_ids (S, kc).
    ``do`` masks which rows insert at all (hit rows pass False and keep
    their state unchanged — LRU stamps included); ``record`` masks the
    (psi, r_a) query record per row (False for degraded back-end
    answers).  The ref tier is a vmap of the scalar ``insert``; the kernel
    tiers run the whole wave's scatter as ONE fused Pallas launch,
    bit-identical per row to the scalar path.
    """
    do, record = _gated_batch(new_ids, do, record)
    be = kdispatch.resolve(backend)
    if be == "ref":
        return _insert_batched_ref(state, cfg, psi, radius, new_emb,
                                   new_ids, do, record)
    new_state, dropped, _ = _insert_batched_kernel(
        state, cfg, psi, radius, new_emb, new_ids, do, record,
        kdispatch.interpret_flag(be))
    return new_state, dropped


@functools.partial(jax.jit, static_argnames=("cfg", "k", "backend"))
def insert_query_batched(state: CacheState, cfg: CacheConfig, psi: jax.Array,
                         radius: jax.Array, new_emb: jax.Array,
                         new_ids: jax.Array, k: int,
                         do: jax.Array | None = None,
                         record: jax.Array | None = None,
                         backend: str | None = None):
    """The serving wave's tail: gated batched insert + post-insert top-k
    query, semantically ``insert_batched`` followed by ``query_batched``.

    On the kernel tiers the pair is ONE fused Pallas launch — the query
    scan scores each cache tile as the insert scatter blends it, so a
    whole ``BatchedEngine`` L1-only miss wave is exactly three launches
    (probe -> miss-search -> insert+query) and a tiered wave four (L1
    probe -> L2 probe -> miss-search -> insert+query).  Returns
    ``((scores, dists, ids, slots), new_state, dropped)``.
    """
    do, record = _gated_batch(new_ids, do, record)
    be = kdispatch.resolve(backend)
    if be == "ref":
        new_state, dropped = _insert_batched_ref(
            state, cfg, psi, radius, new_emb, new_ids, do, record)
        out, new_state = query_batched(new_state, psi, k, backend="ref")
        return out, new_state, dropped
    new_state, dropped, (vals, ids, slots) = _insert_batched_kernel(
        state, cfg, psi, radius, new_emb, new_ids, do, record,
        kdispatch.interpret_flag(be), query_psi=psi, k=k)
    # the scalar query's LRU touch, applied at the post-insert step value
    new_state = _apply_query_touch(new_state, ids, slots)
    return ((vals, emb.distance_from_scores(vals), ids, slots),
            new_state, dropped)


def validate_state(state: CacheState, cfg: CacheConfig, *,
                   n_corpus: int | None = None):
    """Integrity check of a (batched) ``CacheState`` against its layout
    invariants — the fault-domain guard a corrupted session slot is
    quarantined by (``BatchedEngine.quarantine_invalid``) instead of
    poisoning its next wave.

    Checked per row:

    * **counters** — ``0 <= n_docs <= capacity``, ``n_queries >= 0``,
      ``step >= 0``;
    * **occupied prefix** — doc slots ``[0, n_docs)`` hold real ids
      (``>= 0``, and ``< n_corpus`` when given); slots ``[n_docs,
      capacity)`` hold the ``-1`` sentinel;
    * **pad region** — padded doc columns keep their init sentinels
      (id ``-1``, stamp ``0``, scale ``1``) and padded ring slots their
      ``-inf`` radius (the zero-copy launch contract relies on these);
    * **finite payloads** — no NaN/inf in stored embeddings (float
      formats), scales finite and positive, claim radii never NaN or
      ``+inf`` (``-inf`` is the empty/expired-claim sentinel).

    Host-side (numpy) and read-only — call it off the wave hot path.
    Returns ``(ok, problems)``: ``ok`` a bool array over rows (scalar
    for an unbatched state), ``problems`` a list of human-readable
    violation strings.
    """
    batched = np.ndim(np.asarray(state.n_docs)) > 0
    leaves = {f: np.asarray(getattr(state, f)) for f in state._fields}
    if not batched:
        leaves = {f: v[None] for f, v in leaves.items()}
    rows = leaves["n_docs"].shape[0]
    cap, qmax = cfg.capacity, cfg.max_queries
    ok = np.ones((rows,), bool)
    problems: list[str] = []

    def flag(mask, what):
        bad = np.asarray(mask, bool)
        if bad.any():
            ok[bad] = False
            problems.extend(f"row {int(r)}: {what}"
                            for r in np.nonzero(bad)[0])

    n_docs, n_queries, step = (leaves["n_docs"], leaves["n_queries"],
                               leaves["step"])
    flag((n_docs < 0) | (n_docs > cap), "n_docs outside [0, capacity]")
    flag(n_queries < 0, "negative n_queries")
    flag(step < 0, "negative step")
    nd = np.clip(n_docs, 0, cap)[:, None]

    ids = leaves["doc_ids"]
    col = np.arange(ids.shape[1])[None, :]
    occupied, vacant = col < nd, (col >= nd) & (col < cap)
    flag((occupied & (ids < 0)).any(axis=1),
         "sentinel id inside the occupied prefix")
    if n_corpus is not None:
        flag((occupied & (ids >= n_corpus)).any(axis=1),
             "doc id beyond the corpus")
    flag((vacant & (ids != -1)).any(axis=1),
         "non-sentinel id in a vacant slot")
    flag((ids[:, cap:] != -1).any(axis=1), "pad doc slot lost its -1 id")
    flag((leaves["doc_stamp"][:, cap:] != 0).any(axis=1),
         "pad doc slot carries an LRU stamp")
    flag((leaves["doc_scale"][:, cap:] != 1.0).any(axis=1),
         "pad doc slot scale != 1")

    scale = leaves["doc_scale"][:, :cap].astype(np.float32)
    flag((~np.isfinite(scale) | (scale <= 0)).any(axis=1),
         "non-finite or non-positive doc scale")
    qscale = leaves["q_scale"].astype(np.float32)
    flag((~np.isfinite(qscale) | (qscale <= 0)).any(axis=1),
         "non-finite or non-positive query scale")

    rad = leaves["q_radius"].astype(np.float32)
    flag((np.isnan(rad) | (rad == np.inf)).any(axis=1),
         "NaN or +inf claim radius")
    flag((rad[:, qmax:] != -np.inf).any(axis=1),
         "pad ring slot lost its -inf radius sentinel")

    if np.issubdtype(leaves["doc_emb"].dtype, np.integer):
        pass            # int8 payloads cannot encode NaN/inf
    else:
        emb = leaves["doc_emb"][:, :cap].astype(np.float32)
        flag(~np.isfinite(emb).all(axis=(1, 2)),
             "non-finite cached document embedding")
        qemb = leaves["q_emb"].astype(np.float32)
        flag(~np.isfinite(qemb).all(axis=(1, 2)),
             "non-finite claim query embedding")

    return (ok if batched else ok[0]), problems
