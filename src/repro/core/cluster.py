"""Offline k-means clustering over the quantized corpus — topical locality.

The follow-up paper "Efficient Conversational Search via Topical Locality
in Dense Retrieval" observes that conversational queries cluster topically:
successive turns of one conversation stay inside a small neighborhood of
embedding space.  The historical-embedding cache thrives exactly then, so a
backend miss should warm the cache with the *cluster neighborhood* of the
answer, not just the answer documents themselves.

This module builds that neighborhood structure offline as a Pallas workload
riding the existing ``scan_topk`` dispatch contract — no new kernel:

* **assignment step** — batched nearest-centroid search.  The centroids are
  the corpus operand of ``scan_topk`` (ids ``0..K-1``), the documents are
  the queries (dequantized through the shared payload->f32 rule), ``k=1``.
  Because every tier of ``scan_topk`` is rank-identical at a fixed dtype,
  the assignment is tier-identical too (see tests/test_cluster.py).
* **update step** — a ``jax.ops.segment_sum`` centroid refresh.  Embeddings
  are unit-norm after the Eq. 1 transform, so this is *spherical* k-means:
  the refreshed centroid is the renormalized mean; empty clusters keep
  their previous centroid.
* **neighborhood tables** — one more ride on ``scan_topk``, this time over
  the *quantized* corpus payload (centroids as queries, in-kernel
  dequantization), yields each cluster's ``max_width`` nearest documents
  and their centroid distances, sorted ascending.

The product is a :class:`ClusterIndex`: centroids, per-document cluster
ids, per-cluster member lists (CSR, ordered by centrality), and the
neighbor tables.  ``MetricIndex.cluster(...)`` constructs and persists one
(``save``/``load`` round-trips through ``.npz``).

Serving integrations (see docs/architecture.md):

* ``BatchedEngine(cluster=..., prefetch_width=m)`` — on a backend miss the
  fill wave appends the ``m`` nearest-to-centroid documents to the answer
  before the single fused insert+query launch (:meth:`ClusterIndex.prefetch`),
  and soundly *widens* the recorded claim radius: with every document
  within ``d_m`` of centroid ``c`` cached, the triangle inequality
  guarantees every document within ``d_m - ||psi - c||`` of the query is
  cached too, so the claim records ``max(r_a, d_m - ||psi - c||)``.
* ``SharedTier(cluster=...)`` — L2 admission counts distinct sessions per
  *cluster* instead of per document, so topical reuse across sessions
  promotes whole neighborhoods at once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as emb
from repro.kernels import dispatch as kdispatch

__all__ = ["ClusterIndex", "assign_clusters", "build_cluster_index"]


def assign_clusters(docs: np.ndarray, centroids: np.ndarray, *,
                    backend: str | None = None, query_chunk: int = 2048):
    """Nearest-centroid assignment via the ``scan_topk`` kNN contract.

    ``docs`` (n, dim) f32 — typically ``MetricIndex.dequantized()`` rows,
    i.e. the shared-dequantization-rule view of the corpus; ``centroids``
    (K, dim) f32.  The centroids are the scan's corpus operand and the
    documents stream through as query batches of ``query_chunk`` rows, so
    the assignment inherits the tiers' rank-identity guarantee.

    Returns ``(assign (n,) int32, score (n,) f32)`` — the winning centroid
    id per document and its inner-product score.
    """
    be = kdispatch.resolve(backend)
    cents = jnp.asarray(centroids, jnp.float32)
    cids = jnp.arange(cents.shape[0], dtype=jnp.int32)
    from repro.core.metric_index import scan_topk
    out_a, out_s = [], []
    n = docs.shape[0]
    for lo in range(0, n, query_chunk):
        q = jnp.asarray(docs[lo:lo + query_chunk], jnp.float32)
        s, i = scan_topk(cents, cids, q, 1, chunk=int(cents.shape[0]),
                         backend=be)
        out_a.append(np.asarray(i[:, 0]))
        out_s.append(np.asarray(s[:, 0]))
    return (np.concatenate(out_a).astype(np.int32),
            np.concatenate(out_s).astype(np.float32))


@functools.partial(jax.jit, static_argnames=("k",))
def _refresh_centroids(docs, assign, old, k):
    """Segment-sum spherical update: renormalized per-cluster mean; empty
    clusters carry their previous centroid forward."""
    sums = jax.ops.segment_sum(docs, assign, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((docs.shape[0],), jnp.float32),
                                 assign, num_segments=k)
    norms = jnp.linalg.norm(sums, axis=1, keepdims=True)
    fresh = sums / jnp.maximum(norms, 1e-12)
    keep = (counts[:, None] > 0.5) & (norms > 1e-12)
    return jnp.where(keep, fresh, old)


def _kmeanspp_init(docs: np.ndarray, k: int, seed: int) -> np.ndarray:
    """Deterministic k-means++ seeding on the unit sphere (D^2 sampling).

    O(k * n * dim) on host — fine at index-build time; subsample the
    corpus first at very large scale."""
    rng = np.random.default_rng(seed)
    n = docs.shape[0]
    first = int(rng.integers(n))
    cents = [docs[first]]
    # squared distance to nearest chosen centroid; unit vectors => 2 - 2s
    d2 = np.maximum(2.0 - 2.0 * (docs @ cents[0]), 0.0)
    for _ in range(1, k):
        total = float(d2.sum())
        if total <= 0.0:            # corpus exhausted (duplicates)
            cents.append(docs[int(rng.integers(n))])
            continue
        nxt = int(rng.choice(n, p=d2 / total))
        cents.append(docs[nxt])
        d2 = np.minimum(d2, np.maximum(2.0 - 2.0 * (docs @ docs[nxt]), 0.0))
    return np.stack(cents).astype(np.float32)


class ClusterIndex:
    """Topical-locality artifact of :func:`build_cluster_index`.

    Attributes
    ----------
    centroids : (K, dim) f32, unit-norm cluster centers.
    assign : (n_docs,) int32, per-document cluster id (corpus position
        indexed — serving doc ids are corpus positions).
    member_offsets / member_ids : CSR member lists; ``members(c)`` slices
        cluster ``c``'s doc ids, most-central first.
    near_ids / near_d : (K, max_width) neighbor tables — the corpus-wide
        nearest documents to each centroid and their Euclidean centroid
        distances, ascending.  ``near_d[c, m-1]`` is the radius of the
        fully-enumerated ball around centroid ``c`` that a width-``m``
        prefetch caches, which is what lets :meth:`prefetch` return a
        sound claim-radius bound.
    """

    def __init__(self, centroids, assign, member_offsets, member_ids,
                 near_ids, near_d, *, n_iters: int = 0):
        self.centroids = np.asarray(centroids, np.float32)
        self.assign = np.asarray(assign, np.int32)
        self.member_offsets = np.asarray(member_offsets, np.int64)
        self.member_ids = np.asarray(member_ids, np.int64)
        self.near_ids = np.asarray(near_ids, np.int64)
        self.near_d = np.asarray(near_d, np.float32)
        self.n_iters = int(n_iters)

    @property
    def n_clusters(self) -> int:
        """Number of clusters K."""
        return int(self.centroids.shape[0])

    @property
    def n_docs(self) -> int:
        """Number of clustered corpus documents."""
        return int(self.assign.shape[0])

    @property
    def max_width(self) -> int:
        """Widest prefetch the neighbor tables support."""
        return int(self.near_ids.shape[1])

    @property
    def sizes(self) -> np.ndarray:
        """(K,) member counts per cluster."""
        return np.diff(self.member_offsets).astype(np.int64)

    def members(self, c: int) -> np.ndarray:
        """Doc ids of cluster ``c``, most-central first."""
        return self.member_ids[self.member_offsets[c]:self.member_offsets[c + 1]]

    def cluster_of(self, ids) -> np.ndarray:
        """Per-document cluster ids; -1 for out-of-corpus / sentinel ids."""
        ids = np.asarray(ids, np.int64)
        out = np.full(ids.shape, -1, np.int32)
        ok = (ids >= 0) & (ids < self.n_docs)
        out[ok] = self.assign[ids[ok]]
        return out

    def nearest_centroid(self, psi: np.ndarray):
        """(cluster id, Euclidean distance to its centroid) for a unit query."""
        scores = self.centroids @ np.asarray(psi, np.float32)
        c = int(np.argmax(scores))
        delta = float(np.sqrt(max(2.0 - 2.0 * float(scores[c]), 0.0)))
        return c, delta

    def prefetch(self, psi: np.ndarray, answer_ids: np.ndarray, width: int):
        """Expansion set for a backend miss at query ``psi``.

        Returns ``(extra_ids, claim_bound)``: up to ``width`` documents
        nearest the centroid of ``psi``'s cluster that are not already in
        ``answer_ids``, plus the sound claim radius ``d_w - ||psi - c||``
        (triangle inequality; 0.0 when the cluster is farther than its own
        neighborhood radius).  Caching ``answer_ids + extra_ids`` makes
        every document within ``claim_bound`` of ``psi`` cached, so the
        engine may record ``max(r_a, claim_bound)`` for this insert.
        """
        width = min(int(width), self.max_width)
        if width <= 0:
            return np.empty(0, np.int64), 0.0
        c, delta = self.nearest_centroid(psi)
        ids = self.near_ids[c, :width]
        d_w = float(self.near_d[c, width - 1])
        extra = ids[(ids >= 0) & ~np.isin(ids, answer_ids)]
        return extra.astype(np.int64), max(d_w - delta, 0.0)

    def memory_bytes(self) -> int:
        """Host bytes held by the index arrays."""
        return sum(a.nbytes for a in (self.centroids, self.assign,
                                      self.member_offsets, self.member_ids,
                                      self.near_ids, self.near_d))

    def save(self, path) -> None:
        """Persist to ``path`` as an ``.npz`` archive."""
        np.savez(path, centroids=self.centroids, assign=self.assign,
                 member_offsets=self.member_offsets,
                 member_ids=self.member_ids, near_ids=self.near_ids,
                 near_d=self.near_d, n_iters=np.int64(self.n_iters))

    @classmethod
    def load(cls, path) -> "ClusterIndex":
        """Load an index previously written by :meth:`save`."""
        with np.load(path) as z:
            return cls(z["centroids"], z["assign"], z["member_offsets"],
                       z["member_ids"], z["near_ids"], z["near_d"],
                       n_iters=int(z["n_iters"]))


def build_cluster_index(index, n_clusters: int = 64, *, iters: int = 10,
                        seed: int = 0, max_width: int = 256,
                        backend: str | None = None,
                        query_chunk: int = 2048) -> ClusterIndex:
    """Spherical k-means over a ``MetricIndex`` corpus (module docstring).

    ``iters`` bounds the Lloyd iterations (converges early when the
    assignment fixes); ``max_width`` sizes the per-cluster neighbor tables
    and therefore the widest serving-time ``prefetch_width``.  ``backend``
    pins the scan tier for both the assignment and neighbor-table passes
    (``None`` follows the index's own tier).
    """
    be = kdispatch.resolve(backend if backend is not None else index.backend)
    docs = np.asarray(index.dequantized())[:index.n_docs].astype(np.float32)
    n = docs.shape[0]
    k = max(1, min(int(n_clusters), n))
    max_width = max(1, min(int(max_width), n))

    centroids = _kmeanspp_init(docs, k, seed)
    docs_j = jnp.asarray(docs)
    assign = np.full((n,), -1, np.int32)
    n_iters = 0
    for _ in range(max(1, int(iters))):
        n_iters += 1
        new_assign, _ = assign_clusters(docs, centroids, backend=be,
                                        query_chunk=query_chunk)
        if np.array_equal(new_assign, assign):
            break
        assign = new_assign
        centroids = np.asarray(_refresh_centroids(
            docs_j, jnp.asarray(assign), jnp.asarray(centroids), k))

    # Member lists ordered by centrality (score to own centroid, descending).
    assign, own_score = assign_clusters(docs, centroids, backend=be,
                                        query_chunk=query_chunk)
    order = np.lexsort((-own_score, assign))
    member_ids = np.asarray(index.doc_ids[:n], np.int64)[order]
    member_offsets = np.zeros(k + 1, np.int64)
    np.cumsum(np.bincount(assign, minlength=k), out=member_offsets[1:])

    # Neighbor tables: one more scan_topk ride, this time over the
    # *quantized* payload with the in-kernel dequantization rule.
    from repro.core.metric_index import scan_topk
    s, i = scan_topk(index.doc_emb, index.doc_ids,
                     jnp.asarray(centroids, jnp.float32), max_width,
                     chunk=index.chunk, backend=be, scale=index.doc_scale,
                     int8_dot=index.int8_dot)
    near_ids = np.asarray(i, np.int64)
    near_d = np.asarray(emb.distance_from_scores(s), np.float32)

    return ClusterIndex(centroids, assign, member_offsets, member_ids,
                        near_ids, near_d, n_iters=n_iters)
