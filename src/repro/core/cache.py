"""The paper's contribution: a client-side document-embedding metric cache.

This module is the **L1 tier** of the cache hierarchy: the stateful host
wrappers (``MetricCache`` for one conversation, ``BatchedMetricCache`` for
a stacked wave of concurrent sessions) over the tier-agnostic functional
ops that now live in ``repro.core.cache_ops`` — probe / query / insert
over a tile-aligned ``CacheState``.  The cross-session **L2 tier**
(``repro.core.shared.SharedTier``) owns the same ops over the same state
layout, so the hierarchy shares one kernel contract end to end.

Everything that used to be defined here (``CacheState``, ``CacheConfig``,
``init_cache``, the scalar and batched ops) is re-exported below for
backward compatibility — ``from repro.core.cache import probe_batched``
keeps working — but new code should import the functional ops from
``repro.core.cache_ops`` and reserve this module for the host wrappers.

See ``cache_ops`` for the state layout, quantized-storage rules, the
paper-faithful semantics (LowQuality test of Eq. 3/4; no eviction), and
the batched-variant / kernel-dispatch contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cache_ops import (  # noqa: F401  (re-exported contract)
    CacheConfig,
    CacheState,
    ProbeResult,
    dedup_mask,
    evicting_positions,
    init_batched_cache,
    init_cache,
    insert,
    insert_batched,
    insert_positions,
    insert_query_batched,
    pad_features,
    probe,
    probe_batched,
    query,
    query_batched,
    reset_sessions,
    store_rows,
    validate_state,
)
from repro.core.cache_ops import (  # noqa: F401  (internal helpers kernels use)
    _apply_query_touch,
    _gated_batch,
    _insert_batched_kernel,
    _insert_batched_ref,
)
from repro.kernels import dispatch as kdispatch

# Pre-extraction private names, kept so downstream code and docstrings that
# referred to e.g. ``core.cache._insert_positions`` stay truthful.
_pad_features = pad_features
_store_rows = store_rows
_dedup_mask = dedup_mask
_evicting_positions = evicting_positions
_insert_positions = insert_positions

__all__ = ["CacheState", "CacheConfig", "ProbeResult", "init_cache",
           "probe", "query", "insert", "MetricCache", "init_batched_cache",
           "reset_sessions", "probe_batched", "query_batched",
           "insert_batched", "insert_query_batched", "BatchedMetricCache",
           "validate_state"]


class MetricCache:
    """Stateful host wrapper over the functional cache ops."""

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        self.state = init_cache(cfg)
        self.total_dropped = 0

    def reset(self):
        self.state = init_cache(self.cfg)
        self.total_dropped = 0

    @property
    def n_docs(self) -> int:
        return int(self.state.n_docs)

    @property
    def n_queries(self) -> int:
        """Number of *valid* query records (the ring holds the newest)."""
        return int(min(int(self.state.n_queries), self.cfg.max_queries))

    @property
    def total_queries(self) -> int:
        """Total queries ever recorded, including ring-overwritten ones."""
        return int(self.state.n_queries)

    def probe(self, psi, epsilon=None, use_kernel: bool | None = None
              ) -> ProbeResult:
        eps = self.cfg.epsilon if epsilon is None else epsilon
        be = kdispatch.default_backend()
        if use_kernel is None:  # serving default: follow the dispatch tier
            use_kernel = be != "ref"
        if use_kernel:  # fused Pallas probe (TPU; interpret elsewhere)
            from repro.kernels.cache_probe.ops import cache_probe
            st = self.state
            hit, r_hat, idx = cache_probe(
                st.q_emb, psi, st.q_radius, st.n_queries, eps,
                q_scale=st.q_scale, max_queries=self.cfg.max_queries,
                interpret=(None if be == "ref"
                           else kdispatch.interpret_flag(be)))
            return ProbeResult(hit, r_hat, idx)
        return probe(self.state, psi, eps, max_queries=self.cfg.max_queries)

    def query(self, psi, k: int):
        out, self.state = query(self.state, psi, k)
        return out

    def insert(self, psi, radius, new_emb, new_ids, record=True):
        self.state, dropped = insert(self.state, self.cfg, psi, radius,
                                     new_emb, new_ids, record)
        self.total_dropped += int(dropped)

    def memory_bytes(self) -> int:
        """Worst-case occupancy (paper RQ1.C): embeddings dominate — a
        bf16 / int8 ``store_dtype`` cuts the dominant term 2x / 4x."""
        s = self.state
        return sum(int(x.size) * x.dtype.itemsize for x in
                   (s.doc_emb, s.doc_ids, s.doc_stamp, s.q_emb, s.q_radius,
                    s.doc_scale, s.q_scale))


class BatchedMetricCache:
    """Stateful host wrapper over the row-batched functional ops.

    The rows of the stacked ``CacheState`` are SESSIONS here (the L1 tier);
    ``repro.core.shared.SharedTier`` stacks the same state over SHARDS —
    same ops, same kernels, different row meaning.
    """

    def __init__(self, cfg: CacheConfig, n_sessions: int):
        self.cfg = cfg
        self.n_sessions = n_sessions
        self.state = init_batched_cache(cfg, n_sessions)
        self.total_dropped = 0

    def reset(self, sessions=None):
        """Reset all sessions, or just the given session indices."""
        if sessions is None:
            self.state = init_batched_cache(self.cfg, self.n_sessions)
            self.total_dropped = 0
            return
        # write only the target rows (a fresh full stacked state per open
        # would make opening S sessions O(S^2) in state traffic)
        idx = jnp.asarray(sessions)
        fresh = init_cache(self.cfg)
        self.state = jax.tree_util.tree_map(
            lambda full, one: full.at[idx].set(one), self.state, fresh)

    @property
    def n_docs(self):
        return jax.device_get(self.state.n_docs)

    @property
    def n_queries(self):
        return jax.device_get(
            jnp.minimum(self.state.n_queries, self.cfg.max_queries))

    def gather(self, sessions) -> CacheState:
        """Sub-state holding only the given session indices (a wave)."""
        idx = jnp.asarray(sessions)
        return jax.tree_util.tree_map(lambda x: x[idx], self.state)

    def scatter(self, sessions, sub: CacheState):
        """Write a wave's updated sub-state back into the stacked state."""
        idx = jnp.asarray(sessions)
        self.state = jax.tree_util.tree_map(
            lambda full, part: full.at[idx].set(part), self.state, sub)

    def probe(self, psi, epsilon=None, backend=None) -> ProbeResult:
        eps = self.cfg.epsilon if epsilon is None else epsilon
        return probe_batched(self.state, psi, eps, backend=backend,
                             max_queries=self.cfg.max_queries)

    def query(self, psi, k: int, backend=None):
        out, self.state = query_batched(self.state, psi, k, backend=backend)
        return out

    def insert(self, psi, radius, new_emb, new_ids, do=None, record=None,
               backend=None):
        self.state, dropped = insert_batched(
            self.state, self.cfg, psi, radius, new_emb, new_ids, do, record,
            backend=backend)
        self.total_dropped += int(dropped.sum())

    def memory_bytes(self) -> int:
        s = self.state
        return sum(int(x.size) * x.dtype.itemsize for x in
                   (s.doc_emb, s.doc_ids, s.doc_stamp, s.q_emb, s.q_radius,
                    s.doc_scale, s.q_scale))
