"""The paper's contribution: a client-side document-embedding metric cache.

Functional, JAX-native: the cache is a fixed-capacity pytree (``CacheState``)
updated with pure ops, so every operation jits, shards, and fuses with the
query encoder on-device.  A thin host wrapper (``MetricCache``) provides the
stateful convenience API used by the conversational client.

State layout (all pre-allocated; ``-1`` ids / ``-inf`` radii mark empty slots):
  doc_emb   (capacity, dim)   cached transformed document embeddings
  doc_ids   (capacity,)       global document ids, -1 = empty
  doc_stamp (capacity,)       last-use step (for the beyond-paper LRU policy)
  q_emb     (max_queries, dim) embeddings of queries answered by the back-end
  q_radius  (max_queries,)    r_a — distance of the k_c-th doc retrieved
  n_docs, n_queries, step     scalars

Paper-faithful behaviour: no eviction (overflowing inserts are an error in
strict mode / dropped otherwise); the LowQuality test of Eq. 3/4 decides
hits.  Beyond-paper extensions (flagged, off by default): LRU eviction and
distance-based ("ball") eviction so unbounded conversations stay bounded.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import embedding as emb

__all__ = ["CacheState", "CacheConfig", "init_cache", "probe", "query",
           "insert", "MetricCache"]


class CacheState(NamedTuple):
    doc_emb: jax.Array
    doc_ids: jax.Array
    doc_stamp: jax.Array
    q_emb: jax.Array
    q_radius: jax.Array
    n_docs: jax.Array
    n_queries: jax.Array
    step: jax.Array


class CacheConfig(NamedTuple):
    capacity: int
    dim: int
    max_queries: int = 64
    epsilon: float = 0.04      # the paper's tuned default (Fig. 4)
    dedup: bool = True
    eviction: str = "none"     # "none" (paper) | "lru" | "ball" (beyond-paper)
    dtype: object = jnp.float32


def init_cache(cfg: CacheConfig) -> CacheState:
    return CacheState(
        doc_emb=jnp.zeros((cfg.capacity, cfg.dim), cfg.dtype),
        doc_ids=jnp.full((cfg.capacity,), -1, jnp.int32),
        doc_stamp=jnp.zeros((cfg.capacity,), jnp.int32),
        q_emb=jnp.zeros((cfg.max_queries, cfg.dim), cfg.dtype),
        q_radius=jnp.full((cfg.max_queries,), -jnp.inf, cfg.dtype),
        n_docs=jnp.zeros((), jnp.int32),
        n_queries=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


class ProbeResult(NamedTuple):
    hit: jax.Array        # bool — r_hat >= epsilon for some cached query
    r_hat: jax.Array      # max over cached queries of (r_a - delta(psi_a, psi))
    nearest_q: jax.Array  # arg of that max (int32), -1 if cache has no queries


@functools.partial(jax.jit, static_argnames=())
def probe(state: CacheState, psi: jax.Array, epsilon: jax.Array | float) -> ProbeResult:
    """The LowQuality test (Eq. 3/4). Cost: O(n_queries * dim) — a few us.

    Returns hit=False when the cache holds no queries (compulsory miss).
    """
    valid = jnp.arange(state.q_emb.shape[0]) < state.n_queries
    dist = emb.distance_from_scores(state.q_emb @ psi)           # (max_queries,)
    r_hat = jnp.where(valid, state.q_radius - dist, -jnp.inf)
    best = jnp.argmax(r_hat)
    best_r = r_hat[best]
    hit = jnp.logical_and(state.n_queries > 0, best_r >= epsilon)
    return ProbeResult(hit, best_r, jnp.where(state.n_queries > 0, best, -1))


@functools.partial(jax.jit, static_argnames=("k",))
def query(state: CacheState, psi: jax.Array, k: int):
    """NN(C, psi, k): top-k cached docs. Returns (scores, distances, ids, slots)."""
    scores = state.doc_emb @ psi                                  # (capacity,)
    scores = jnp.where(state.doc_ids >= 0, scores, -jnp.inf)
    top_s, slots = jax.lax.top_k(scores, k)
    ids = state.doc_ids[slots]
    # touch LRU stamps of returned docs
    new_stamp = state.doc_stamp.at[slots].set(state.step)
    state = state._replace(doc_stamp=new_stamp, step=state.step + 1)
    return (top_s, emb.distance_from_scores(top_s), ids, slots), state


def _dedup_mask(new_ids: jax.Array, existing_ids: jax.Array) -> jax.Array:
    """True for the first occurrence of each id not already cached."""
    in_cache = (new_ids[:, None] == existing_ids[None, :]).any(axis=1)
    kc = new_ids.shape[0]
    ii, jj = jnp.triu_indices(kc, k=1)  # j > i pairs
    dup_later = jnp.zeros((kc,), bool).at[jj].max(new_ids[jj] == new_ids[ii])
    return jnp.logical_and(~in_cache, ~dup_later)


@functools.partial(jax.jit, static_argnames=("cfg",))
def insert(state: CacheState, cfg: CacheConfig, psi: jax.Array, radius: jax.Array,
           new_emb: jax.Array, new_ids: jax.Array) -> tuple[CacheState, jax.Array]:
    """Insert the k_c back-end results for a missed query ``psi``.

    Records (psi, r_a) for future LowQuality probes, then appends the new
    document embeddings (deduplicated by id when cfg.dedup).  Returns
    (new_state, n_dropped) where n_dropped counts docs that did not fit
    (always 0 under the paper's sizing assumption; >0 triggers eviction when
    cfg.eviction != "none").
    """
    kc = new_ids.shape[0]
    keep = _dedup_mask(new_ids, state.doc_ids) if cfg.dedup else jnp.ones((kc,), bool)

    if cfg.eviction == "lru":
        # Beyond-paper: rank existing slots by staleness; overflow overwrites
        # the stalest slots instead of dropping.
        n_new = keep.sum()
        overflow = jnp.maximum(0, state.n_docs + n_new - cfg.capacity)
        # staleness order: empty slots first (stamp -1), then oldest stamps
        stamp = jnp.where(state.doc_ids >= 0, state.doc_stamp, -1)
        evict_order = jnp.argsort(stamp)                       # stalest first
        # positions: fill empty tail first, then evict stalest
        append_pos = state.n_docs + jnp.cumsum(keep) - 1
        evict_pos = evict_order[jnp.cumsum(keep) - 1]
        pos = jnp.where(append_pos < cfg.capacity, append_pos, evict_pos)
        pos = jnp.where(keep, pos, cfg.capacity)               # dropped -> OOB
        dropped = jnp.zeros((), jnp.int32)
        new_n = jnp.minimum(state.n_docs + n_new, cfg.capacity)
    elif cfg.eviction == "ball":
        # Beyond-paper: overflow evicts docs farthest from the current query.
        n_new = keep.sum()
        d_exist = emb.distance_from_scores(state.doc_emb @ psi)
        d_exist = jnp.where(state.doc_ids >= 0, d_exist, jnp.inf)  # empty first... (inf = best target)
        evict_order = jnp.argsort(-jnp.where(jnp.isinf(d_exist), 1e9, d_exist))
        append_pos = state.n_docs + jnp.cumsum(keep) - 1
        evict_pos = evict_order[jnp.cumsum(keep) - 1]
        pos = jnp.where(append_pos < cfg.capacity, append_pos, evict_pos)
        pos = jnp.where(keep, pos, cfg.capacity)
        dropped = jnp.zeros((), jnp.int32)
        new_n = jnp.minimum(state.n_docs + n_new, cfg.capacity)
    else:  # paper-faithful: append, drop overflow (and report it)
        append_pos = state.n_docs + jnp.cumsum(keep) - 1
        fits = append_pos < cfg.capacity
        pos = jnp.where(jnp.logical_and(keep, fits), append_pos, cfg.capacity)
        dropped = jnp.logical_and(keep, ~fits).sum().astype(jnp.int32)
        new_n = jnp.minimum(state.n_docs + keep.sum(), cfg.capacity)

    doc_emb = state.doc_emb.at[pos].set(new_emb, mode="drop")
    doc_ids = state.doc_ids.at[pos].set(new_ids, mode="drop")
    doc_stamp = state.doc_stamp.at[pos].set(state.step, mode="drop")

    qslot = jnp.minimum(state.n_queries, state.q_emb.shape[0] - 1)
    q_emb = state.q_emb.at[qslot].set(psi)
    q_radius = state.q_radius.at[qslot].set(radius)

    new_state = CacheState(
        doc_emb=doc_emb, doc_ids=doc_ids, doc_stamp=doc_stamp,
        q_emb=q_emb, q_radius=q_radius,
        n_docs=new_n.astype(jnp.int32),
        n_queries=jnp.minimum(state.n_queries + 1, state.q_emb.shape[0]).astype(jnp.int32),
        step=state.step + 1,
    )
    return new_state, dropped


class MetricCache:
    """Stateful host wrapper over the functional cache ops."""

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        self.state = init_cache(cfg)
        self.total_dropped = 0

    def reset(self):
        self.state = init_cache(self.cfg)
        self.total_dropped = 0

    @property
    def n_docs(self) -> int:
        return int(self.state.n_docs)

    @property
    def n_queries(self) -> int:
        return int(self.state.n_queries)

    def probe(self, psi, epsilon=None, use_kernel: bool = False) -> ProbeResult:
        eps = self.cfg.epsilon if epsilon is None else epsilon
        if use_kernel:  # fused Pallas probe (TPU; interpret elsewhere)
            from repro.kernels.cache_probe.ops import cache_probe
            st = self.state
            hit, r_hat, idx = cache_probe(st.q_emb, psi, st.q_radius,
                                          st.n_queries, eps)
            return ProbeResult(hit, r_hat, idx)
        return probe(self.state, psi, eps)

    def query(self, psi, k: int):
        out, self.state = query(self.state, psi, k)
        return out

    def insert(self, psi, radius, new_emb, new_ids):
        self.state, dropped = insert(self.state, self.cfg, psi, radius, new_emb, new_ids)
        self.total_dropped += int(dropped)

    def memory_bytes(self) -> int:
        """Worst-case occupancy (paper RQ1.C): embeddings dominate."""
        s = self.state
        return sum(int(x.size) * x.dtype.itemsize for x in
                   (s.doc_emb, s.doc_ids, s.doc_stamp, s.q_emb, s.q_radius))
