"""Algorithm 1 — the conversational client gluing cache and back-end.

The hit/miss branch is host-level control flow (a miss performs a remote
index round-trip), so the driver is a small host loop over jitted device ops:
``probe`` -> (hit: cache ``query``) | (miss: back-end ``search`` + ``insert``
+ cache ``query``).

``ConversationalSearcher`` also accumulates the telemetry the paper reports:
per-utterance hit/miss, coverage vs. the exact index answer, and timing.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheConfig, MetricCache
from repro.core.metric_index import MetricIndex, SearchResult

__all__ = ["TurnRecord", "ConversationalSearcher"]


@dataclass
class TurnRecord:
    hit: bool
    r_hat: float
    ids: np.ndarray
    distances: np.ndarray
    coverage: Optional[float]
    cache_docs: int
    latency_s: float


@dataclass
class ConversationalSearcher:
    """The client of Fig. 2: encoder -> CACHE -> (maybe) back-end index.

    policy: "dynamic" (Algorithm 1), "static" (fill once, never update), or
    "none" (no cache; every query hits the back-end — the paper's baseline).
    """
    index: MetricIndex
    k: int = 10
    k_c: int = 1000
    epsilon: float = 0.04
    policy: str = "dynamic"
    cache_capacity: Optional[int] = None     # default: 16 updates worth of k_c
    max_queries: int = 64
    eviction: str = "none"
    dedup: bool = True
    measure_coverage: bool = False           # compare vs. exact index answers
    encoder: Optional[Callable] = None       # raw query -> psi (else pass psi)
    history: list = field(default_factory=list)

    def __post_init__(self):
        cap = self.cache_capacity or 16 * self.k_c
        # the client cache stores embeddings in the index's dtype policy, so
        # a quantized deployment shrinks client memory by the same factor
        cfg = CacheConfig(capacity=cap, dim=self.index.dim,
                          max_queries=self.max_queries, epsilon=self.epsilon,
                          dedup=self.dedup, eviction=self.eviction,
                          store_dtype=self.index.dtype)
        self.cache = MetricCache(cfg)

    # -- conversation lifecycle -------------------------------------------
    def start_conversation(self):
        self.cache.reset()
        self.history = []

    # -- Algorithm 1 -------------------------------------------------------
    def answer(self, query) -> TurnRecord:
        psi = self.encoder(query) if self.encoder is not None else jnp.asarray(query)
        t0 = time.perf_counter()

        if self.policy == "none":
            res = self.index.search(psi[None], self.k)
            rec = self._record(hit=False, r_hat=float("-inf"), res=res, psi=psi, t0=t0)
            self.history.append(rec)
            return rec

        pr = self.cache.probe(psi)
        empty = self.cache.n_queries == 0
        # static policy never updates after the first fill
        low_quality = empty or (self.policy == "dynamic" and not bool(pr.hit))

        if low_quality:
            backend: SearchResult = self.index.search(psi[None], self.k_c)
            radius = backend.distances[0, -1]          # r_a: k_c-th NN distance
            # f32 view, not the raw payload: a bf16/int8 index stores a
            # quantized doc_emb whose magnitude lives in doc_scale
            doc_emb = self.index.dequantized()[self._slots_for(backend.ids[0])]
            self.cache.insert(psi, radius, doc_emb, backend.ids[0])

        scores, dists, ids, _ = self.cache.query(psi, self.k)
        res = SearchResult(scores[None], dists[None], ids[None])
        rec = self._record(hit=not low_quality, r_hat=float(pr.r_hat), res=res,
                           psi=psi, t0=t0)
        self.history.append(rec)
        return rec

    def _slots_for(self, ids: jax.Array) -> jax.Array:
        # MetricIndex stores docs in id order by construction (ids == row
        # index for generated corpora); fall back to a search when not.
        return ids

    def _record(self, *, hit, r_hat, res: SearchResult, psi, t0) -> TurnRecord:
        cov = None
        if self.measure_coverage:
            exact = self.index.search(psi[None], self.k)
            cov = float(np.isin(np.asarray(res.ids[0]), np.asarray(exact.ids[0])).mean())
        return TurnRecord(
            hit=bool(hit), r_hat=r_hat,
            ids=np.asarray(res.ids[0]), distances=np.asarray(res.distances[0]),
            coverage=cov, cache_docs=self.cache.n_docs,
            latency_s=time.perf_counter() - t0,
        )

    # -- telemetry ----------------------------------------------------------
    def hit_rate(self, skip_first: bool = True) -> float:
        """Paper convention: the compulsory first miss is excluded."""
        turns = self.history[1:] if skip_first else self.history
        if not turns:
            return float("nan")
        return float(np.mean([t.hit for t in turns]))

    def mean_coverage(self) -> float:
        covs = [t.coverage for t in self.history if t.coverage is not None]
        return float(np.mean(covs)) if covs else float("nan")
