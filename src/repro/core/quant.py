"""Quantized corpus storage for the bandwidth-bound scan contract.

The fused kNN scan streams the whole corpus through VMEM once per wave, so
on TPU it is HBM-bandwidth bound (see ``kernels.knn``): at fp32 a 768(+1)-d
STAR corpus costs ~3 KB of HBM traffic per document per scan.  Storing the
corpus in bf16 or int8 cuts that traffic 2x / 4x — the scan's effective
bandwidth rises by the same factor because the kernel dequantizes tiles in
VMEM (registers), never in HBM.

Formats (``DTYPES``):

  * ``fp32`` — identity; the oracle representation.
  * ``bf16`` — elementwise downcast; no scale array.
  * ``int8`` — symmetric per-document quantization with an fp32 scale per
    row, *unit-norm-preserving*: the scale is chosen as
    ``||x|| / ||q_int||`` (not the usual ``amax/127``) so the dequantized
    row has exactly the norm of the original.  Transformed embeddings
    (Eq. 1) live on the unit sphere, and the whole metric machinery
    (``distance_from_scores``, hyperball containment, the LowQuality test)
    assumes unit vectors — preserving the norm keeps score -> distance
    conversions consistent to fp32 rounding.

Dequantization rule shared by EVERY scan tier (this is what makes the three
dispatch tiers bit-identical at a fixed dtype):

    scores = (q_f32 @ data.astype(f32).T) * scale        # score-side scale

i.e. the integer (or bf16) payload is cast to f32, the dot runs in f32, and
the per-document scale multiplies the *score*.  Rank equality vs the fp32
corpus is tolerance-bound, not exact (documented floors live in
``tests/test_kernel_equivalence.py`` and the README table).

``REPRO_CORPUS_DTYPE`` pins the default for a whole process (the CI kernel
gate runs the matrix {fp32, bf16, int8} x {ref, interpret} this way);
components with a ``dtype=None`` policy argument (``MetricIndex``,
``DeviceShard``, the serving engines' cache storage) resolve through
``default_dtype()``.
"""

from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["DTYPES", "QuantizedCorpus", "default_dtype", "resolve_dtype",
           "storage_dtype", "itemsize", "quantize", "dequantize",
           "scale_scores", "int8_dot_default", "resolve_int8_dot"]

DTYPES = ("fp32", "bf16", "int8")

_STORAGE = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}


class QuantizedCorpus(NamedTuple):
    """A corpus in one of the ``DTYPES`` formats.

    data:  (n, d) payload in ``storage_dtype(dtype)``.
    scale: (n,) f32 per-document score multipliers, or None (fp32 / bf16).
    dtype: the format name (static; not a jax type).
    """

    data: jax.Array
    scale: Optional[jax.Array]
    dtype: str


def default_dtype() -> str:
    """Process-wide corpus dtype policy (``REPRO_CORPUS_DTYPE``, else fp32)."""
    env = os.environ.get("REPRO_CORPUS_DTYPE", "").strip().lower()
    if not env:
        return "fp32"
    if env not in DTYPES:
        raise ValueError(
            f"REPRO_CORPUS_DTYPE={env!r}: expected one of {DTYPES}")
    return env


def resolve_dtype(dtype: Optional[str]) -> str:
    """Validate ``dtype``; None resolves to the process default."""
    if dtype is None:
        return default_dtype()
    if dtype not in DTYPES:
        raise ValueError(f"dtype {dtype!r}: expected one of {DTYPES}")
    return dtype


def int8_dot_default() -> bool:
    """Process-wide policy for the native int8 MXU dot (``REPRO_INT8_DOT``).

    When enabled *and* the corpus payload is int8, the scan quantizes the
    queries per-row to int8 and runs the dot int8 x int8 with int32
    accumulation — the MXU's native narrow mode — applying both fp32
    scales score-side.  Off (the default) the scan keeps the
    dequantize-first rule, which is the exact-parity tier vs fp32 at a
    fixed dtype.  The int8-dot tier trades a little extra rank drift
    (gated at the established int8 floor, >= 0.90 overlap) for compute
    headroom on top of the 4x bandwidth win.
    """
    env = os.environ.get("REPRO_INT8_DOT", "").strip().lower()
    return env in ("1", "true", "yes", "on")


def resolve_int8_dot(flag: Optional[bool], payload_dtype) -> bool:
    """Concrete int8-dot decision for a scan: the explicit ``flag`` (env
    policy when None), active only for an int8 payload — the flag is
    ignored, never an error, on wider corpora."""
    use = int8_dot_default() if flag is None else bool(flag)
    return use and jnp.dtype(payload_dtype) == jnp.int8


def storage_dtype(dtype: str):
    """The jnp element type backing a format."""
    return _STORAGE[resolve_dtype(dtype)]


def itemsize(dtype: str) -> int:
    """Bytes per element streamed from HBM for a format's payload."""
    return jnp.dtype(storage_dtype(dtype)).itemsize


def quantize(x: jax.Array, dtype: Optional[str] = None) -> QuantizedCorpus:
    """Quantize (n, d) f32 rows into a ``QuantizedCorpus``.

    Pure jnp — safe inside jit/vmap (``dtype`` must then be static).
    int8 rows quantize symmetrically per document; the fp32 scale is
    renormalized so the dequantized row keeps the original row's norm
    exactly (see module docstring).  All-zero rows (sentinel padding)
    quantize to zero payload with scale 1.
    """
    dtype = resolve_dtype(dtype)
    x = jnp.asarray(x)
    if dtype == "fp32":
        return QuantizedCorpus(x.astype(jnp.float32), None, dtype)
    if dtype == "bf16":
        return QuantizedCorpus(x.astype(jnp.bfloat16), None, dtype)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    step = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / step), -127, 127).astype(jnp.int8)
    qnorm = jnp.linalg.norm(q.astype(jnp.float32), axis=-1)
    xnorm = jnp.linalg.norm(x.astype(jnp.float32), axis=-1)
    scale = jnp.where(qnorm > 0, xnorm / jnp.maximum(qnorm, 1e-30), 1.0)
    return QuantizedCorpus(q, scale.astype(jnp.float32), dtype)


def dequantize(qc: QuantizedCorpus) -> jax.Array:
    """f32 view of the payload (the value every scan tier scores against)."""
    x = qc.data.astype(jnp.float32)
    if qc.scale is None:
        return x
    return x * qc.scale[..., None]


def scale_scores(scores: jax.Array, scale: Optional[jax.Array]) -> jax.Array:
    """Apply the score-side per-document scale: (..., n) * (n,) -> (..., n).

    The shared dequantization rule of the scan contract: every tier scores
    the raw payload in f32 and multiplies the score by the document scale,
    so tiers agree bitwise at a fixed dtype.  No-op when scale is None.
    """
    if scale is None:
        return scores
    return scores * scale
