"""Physical cache-state layout: the TPU tiling extents, in one place.

The metric cache's logical extents (``capacity``, ``dim``, ``max_queries``
in ``CacheConfig``) are whatever the serving configuration asks for; the
Pallas wave kernels want lane-aligned feature dims and tile-aligned
capacities.  Since ISSUE 6 the ``CacheState`` leaves are allocated at the
*physical* extents once, at ``init_cache`` time — capacity rounded up to
the ``cache_wave`` tile multiple, feature dim to the lane multiple, the
query-record ring to the sublane multiple — so every kernel launch is
zero-copy: no per-launch pad of the stacked ``(S, capacity, dim)`` payload
in, no slice back out.  Only per-wave inputs (the k_c new documents, the
wave's queries) still get padded, which is O(wave), not O(capacity).

This module owns the rounding rules so ``core.cache`` (allocation +
masking) and ``kernels.cache_wave`` / ``kernels.cache_probe`` (launch
geometry) cannot drift apart.  Padded slots carry the empty-slot
sentinels (doc id -1, scale 1.0, radius -inf, stamp 0, zero payload) and
the ops mask on the *logical* extents, so the pads are invisible to every
result.
"""

from __future__ import annotations

LANE = 128      # TPU lane multiple: feature (last) axis of VMEM blocks
SUBLANE = 8     # TPU sublane multiple: second-to-last axis

__all__ = ["LANE", "SUBLANE", "round_up", "wave_tile", "phys_capacity",
           "phys_dim", "phys_queries"]


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def wave_tile(capacity: int) -> int:
    """Capacity tile of the wave kernels: one power of two <= 512 (the
    whole cache when smaller)."""
    pow2 = max(SUBLANE, 1 << max(capacity - 1, 1).bit_length())
    return min(512, pow2)


def phys_capacity(capacity: int) -> int:
    """Physical doc-slot count: capacity rounded to the wave tile multiple
    (== the next power of two for capacities up to 512)."""
    return round_up(capacity, wave_tile(capacity))


def phys_dim(dim: int) -> int:
    """Physical feature width: dim rounded to the lane multiple."""
    return round_up(dim, LANE)


def phys_queries(max_queries: int) -> int:
    """Physical query-record ring length: rounded to the sublane multiple."""
    return round_up(max_queries, SUBLANE)
