from repro.core.cache import CacheConfig, CacheState, MetricCache, init_cache
from repro.core.conversation import ConversationalSearcher, TurnRecord
from repro.core.embedding import (distance_from_scores, pairwise_distances,
                                  pairwise_scores, transform_documents,
                                  transform_queries)
from repro.core.metric_index import MetricIndex, SearchResult, chunked_nn, exact_nn
from repro.core.quant import DTYPES, QuantizedCorpus, dequantize, quantize

__all__ = [
    "CacheConfig", "CacheState", "MetricCache", "init_cache",
    "ConversationalSearcher", "TurnRecord",
    "distance_from_scores", "pairwise_distances", "pairwise_scores",
    "transform_documents", "transform_queries",
    "MetricIndex", "SearchResult", "chunked_nn", "exact_nn",
    "DTYPES", "QuantizedCorpus", "dequantize", "quantize",
]
