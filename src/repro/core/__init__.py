"""Core layer: metric cache (L1/L2 tiers + device ops), exact metric index,
embedding transform (Eq. 1), quantized corpus storage, and the offline
topical clustering subsystem behind cluster prefetch."""

from repro.core.cache import (BatchedMetricCache, CacheConfig, CacheState,
                              MetricCache, init_cache)
from repro.core.cluster import ClusterIndex, build_cluster_index
from repro.core.shared import SharedTier
from repro.core.conversation import ConversationalSearcher, TurnRecord
from repro.core.embedding import (distance_from_scores, pairwise_distances,
                                  pairwise_scores, transform_documents,
                                  transform_queries)
from repro.core.metric_index import MetricIndex, SearchResult, chunked_nn, exact_nn
from repro.core.quant import DTYPES, QuantizedCorpus, dequantize, quantize

__all__ = [
    "BatchedMetricCache", "CacheConfig", "CacheState", "MetricCache",
    "init_cache", "ClusterIndex", "build_cluster_index", "SharedTier",
    "ConversationalSearcher", "TurnRecord",
    "distance_from_scores", "pairwise_distances", "pairwise_scores",
    "transform_documents", "transform_queries",
    "MetricIndex", "SearchResult", "chunked_nn", "exact_nn",
    "DTYPES", "QuantizedCorpus", "dequantize", "quantize",
]
