"""Cross-session shared cache tier (L2) with semantic result reuse.

The paper's metric cache is session-private, but its premise — temporal
and topical locality of conversational queries — holds *across* users at
scale: the same topics recur in many concurrent sessions, so the real
hit-rate ceiling is global.  ``SharedTier`` is that global tier: a
sharded, TTL'd embedding cache sitting between the per-session L1 caches
and the back-end router (probe order: L1 -> L2 -> back-end).

It is deliberately NOT a new cache implementation.  An L2 shard is one
row of the same stacked, tile-aligned ``CacheState`` the L1 tier uses,
driven by the same tier-agnostic ops (``repro.core.cache_ops``): the L2
probe is ``probe_batched`` (one fused ``cache_probe_batched`` launch over
the gathered shard rows), L2 answers come from ``query_batched``, and
admission inserts ride ``insert_batched`` — same kernels, same dispatch
tiers, no new kernel contract.  Shards use the beyond-paper LRU eviction
(``eviction="lru"``) because a shared tier, unlike a per-conversation
cache, must run indefinitely under churn.

Three mechanisms distinguish the tier from a big L1:

* **Shard routing.**  A query goes to ``argmax(psi @ R)`` for a fixed
  seeded Gaussian ``R`` (dim, n_shards) — a locality-sensitive split, so
  topically close queries from different sessions land in the same shard
  and see each other's promotions.

* **Admission policy.**  A back-end answer is *offered* to the tier, not
  inserted: per-document we count the distinct session tokens that
  retrieved it, and only when at least ``admission_frac`` of an answer's
  documents have been retrieved by >= ``admission_sessions`` distinct
  sessions is the whole answer — the (psi, r_a) coverage claim plus all
  k_c documents together — promoted.  Promoting the answer wholesale
  keeps the claim sound: a claim whose documents were partially admitted
  could serve a future hit from an incomplete document set.  One-off
  off-topic queries never clear the bar, so they cannot pollute the
  shared tier (the admission-control direction in ROADMAP).

  With a ``repro.core.cluster.ClusterIndex`` attached (``cluster=``), the
  popularity unit coarsens from the document to its *topical cluster*:
  distinct sessions are counted per cluster id, so two sessions
  retrieving different documents of the same topic still clear the bar
  together.  That matches how conversational reuse actually arrives —
  sessions share topics, rarely exact result sets — and lets the tier
  warm a topic after ``admission_sessions`` sessions touch it from any
  angle, while one-session topics still never promote.

* **Semantic result reuse.**  The tier memoizes recent
  ``(query embedding, top-k_c result)`` pairs from fresh back-end
  retrievals.  A near-duplicate query from ANOTHER session — cosine
  similarity >= ``memo_sim`` (embeddings are unit-norm after the Eq. 1
  transform, so the dot product IS the cosine) — is served the memoized
  result set directly, skipping the back-end entirely.  The similarity
  floor is calibrated against the rank-overlap quality gate (reused
  result sets must overlap >= 0.95 with fresh retrieval; gated in tests
  and ``check_regression``).  Reuse feeds admission too, with the
  triangle-corrected claim radius ``r_a - delta(psi_a, psi)`` — exactly
  the paper's Eq. 3 bound, so the promoted claim stays sound.

**TTL.**  Shared coverage claims go stale as the corpus and topic mix
drift, so every claim and memo entry carries the wave number when it was
recorded; ``tick()`` (called once per serving wave) retires claims older
than ``ttl_waves`` by restoring their ring slots' -inf radius sentinel.
Documents themselves are not TTL'd: a document embedding never goes
stale, claims do; cold documents age out through LRU eviction instead
(expiring a doc mid-array would also break the append-only occupied-
prefix invariant the insert positions rely on).

Host-side bookkeeping (admission counts, the memo ring, claim stamps) is
numpy; everything touching embeddings at scale is the shared kernel path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cache_ops import (CacheConfig, CacheState, ProbeResult,
                                  init_batched_cache, insert_batched,
                                  probe_batched, query_batched)
from repro.kernels import dispatch as kdispatch

__all__ = ["SharedTier"]

_NEVER = -(2 ** 62)  # claim/memo stamp for "never written"


class SharedTier:
    """Sharded, TTL'd, cross-session L2 embedding cache + result memo."""

    def __init__(self, *, dim: int, n_shards: int = 4, capacity: int = 4096,
                 max_queries: int = 256, epsilon: float = 0.04,
                 ttl_waves: Optional[int] = 512,
                 admission_sessions: int = 2, admission_frac: float = 0.5,
                 admission_table_max: int = 1_000_000,
                 memo_size: int = 256, memo_sim: float = 0.995,
                 cluster=None,
                 dtype: Optional[str] = None, backend: Optional[str] = None,
                 seed: int = 0):
        self.cfg = CacheConfig(capacity=capacity, dim=dim,
                               max_queries=max_queries, epsilon=epsilon,
                               eviction="lru",
                               store_dtype=quant.resolve_dtype(dtype))
        self.n_shards = n_shards
        self.backend = kdispatch.resolve(backend)
        self.state: CacheState = init_batched_cache(self.cfg, n_shards)
        # locality-sensitive shard router: fixed so a topic always routes to
        # the same shard across sessions and process restarts
        self._router = np.random.default_rng(seed).standard_normal(
            (dim, n_shards)).astype(np.float32)
        self.ttl_waves = ttl_waves
        self.wave = 0
        # per-ring-slot wave stamp for claim TTL (host; (n_shards, Qp))
        qp = self.cfg.phys_max_queries
        self._claim_wave = np.full((n_shards, qp), _NEVER, np.int64)
        self._claim_alive = np.zeros((n_shards, qp), bool)
        # admission: popularity unit -> distinct session tokens (capped —
        # once the bar is met there is nothing more to learn).  The unit
        # is the doc id, or its topical cluster id when a ClusterIndex is
        # attached (cluster-aware admission; see module docstring).
        self.admission_sessions = admission_sessions
        self.admission_frac = admission_frac
        self.admission_table_max = admission_table_max
        self.cluster = cluster
        self._seen: dict[int, set] = {}
        self._pending: list[tuple] = []
        # semantic result memo: ring of (psi, ids, scores, r_a, token, wave)
        self.memo_size = memo_size
        self.memo_sim = memo_sim
        self._memo_psi: Optional[np.ndarray] = None   # (M, dim) f32
        self._memo_ids: Optional[np.ndarray] = None   # (M, k_c)
        self._memo_scores: Optional[np.ndarray] = None
        self._memo_radius = np.zeros((memo_size,), np.float32)
        self._memo_token: list = [None] * memo_size
        self._memo_wave = np.full((memo_size,), _NEVER, np.int64)
        self._memo_n = 0
        # counters (reported by serve_bench)
        self.n_promoted = 0          # answers admitted into the shard caches
        self.n_offered = 0
        self.n_memo_served = 0
        self.n_stale_served = 0      # memo serves under allow_stale outage
        self.total_dropped = 0

    # ---------------------------------------------------------------- waves

    def tick(self) -> None:
        """Advance the wave clock; retire coverage claims past their TTL by
        restoring the ring slot's -inf radius sentinel (the document
        payload stays — embeddings don't go stale, claims do)."""
        self.wave += 1
        if self.ttl_waves is None:
            return
        stale = np.logical_and(
            self._claim_alive,
            self.wave - self._claim_wave > self.ttl_waves)
        if stale.any():
            self.state = self.state._replace(
                q_radius=jnp.where(jnp.asarray(stale), -jnp.inf,
                                   self.state.q_radius))
            self._claim_alive[stale] = False

    # -------------------------------------------------------------- routing

    def route(self, psi: np.ndarray) -> np.ndarray:
        """Shard index per query row: argmax over the fixed Gaussian
        projections (locality-sensitive — near-duplicate queries always
        agree on the shard)."""
        return np.argmax(np.asarray(psi, np.float32) @ self._router, axis=1)

    def _gather(self, shards: np.ndarray) -> CacheState:
        idx = jnp.asarray(shards)
        return jax.tree_util.tree_map(lambda x: x[idx], self.state)

    def _scatter(self, shards: np.ndarray, sub: CacheState) -> None:
        idx = jnp.asarray(shards)
        self.state = jax.tree_util.tree_map(
            lambda full, part: full.at[idx].set(part), self.state, sub)

    # ------------------------------------------------------------ probe path

    def probe_rows(self, psi, shards: np.ndarray,
                   backend: Optional[str] = None) -> ProbeResult:
        """The L2 LowQuality test for a wave: one ``cache_probe_batched``
        launch over the gathered shard rows (duplicate shards in one wave
        just gather the same row twice — the probe is read-only)."""
        sub = self._gather(shards)
        return probe_batched(sub, psi, self.cfg.epsilon,
                             backend=backend or self.backend,
                             max_queries=self.cfg.max_queries)

    def query_rows(self, psi, shards: np.ndarray, k: int,
                   backend: Optional[str] = None):
        """Top-k cached docs per wave row from its shard (one fused launch).
        LRU touches are scattered back best-effort; when one wave queries
        the same shard twice, one row's stamp refresh wins — acceptable
        for an eviction heuristic, and the payload is read-only."""
        assert k <= self.cfg.capacity, "L2 answer k exceeds shard capacity"
        sub = self._gather(shards)
        out, sub = query_batched(sub, psi, k, backend=backend or self.backend)
        self._scatter(shards, sub)
        return out

    # ------------------------------------------------------------- admission

    def offer(self, token, psi, radius: float, emb, ids) -> bool:
        """Offer one back-end (or reused) answer for promotion.

        Counts ``token`` toward every document in the answer; when at
        least ``admission_frac`` of the answer's documents have been
        retrieved by >= ``admission_sessions`` distinct sessions, the
        WHOLE answer — claim and documents together — is queued for
        promotion (flushed at end of wave by ``flush_admissions`` so
        admission never adds launches to the serving wave itself).
        Returns whether the answer was queued.
        """
        ids = np.asarray(ids)
        real = ids >= 0
        if not real.any():
            return False
        self.n_offered += 1
        if len(self._seen) > self.admission_table_max:
            # coarse pressure valve: restart the popularity counts rather
            # than let the host table grow without bound
            self._seen.clear()
        if self.cluster is not None:
            # cluster-aware: vote once per distinct topical cluster, then
            # count a doc promotable iff its CLUSTER cleared the bar
            # (out-of-corpus ids fall back to per-doc keys, negated so
            # they can never collide with cluster ids)
            cids = self.cluster.cluster_of(ids[real])
            keys = [int(c) if c >= 0 else -(int(d) + 1)
                    for c, d in zip(cids, ids[real])]
            for ck in set(keys):
                s = self._seen.setdefault(ck, set())
                if len(s) < self.admission_sessions:
                    s.add(token)
            promotable = sum(
                1 for ck in keys
                if len(self._seen[ck]) >= self.admission_sessions)
        else:
            promotable = 0
            for d in ids[real].tolist():
                s = self._seen.setdefault(d, set())
                if len(s) < self.admission_sessions:
                    s.add(token)
                if len(s) >= self.admission_sessions:
                    promotable += 1
        if promotable < self.admission_frac * int(real.sum()):
            return False
        shard = int(self.route(np.asarray(psi, np.float32)[None])[0])
        self._pending.append((shard, np.asarray(psi, np.float32),
                              float(radius), np.asarray(emb),
                              ids.astype(np.int32)))
        return True

    def flush_admissions(self, backend: Optional[str] = None) -> int:
        """Insert the wave's admitted answers into their shards.

        Answers bound for distinct shards batch into one
        ``insert_batched`` launch; same-shard answers split into ordered
        sub-waves (two inserts into one gathered row copy would lose one
        of them at scatter).  Claim ring slots are wave-stamped for TTL.
        """
        pending, self._pending = self._pending, []
        promoted = 0
        while pending:
            seen: set = set()
            now, later = [], []
            for p in pending:
                (now if p[0] not in seen else later).append(p)
                seen.add(p[0])
            shards = np.array([p[0] for p in now], np.int32)
            psi = jnp.asarray(np.stack([p[1] for p in now]))
            radius = jnp.asarray(np.array([p[2] for p in now], np.float32))
            emb = jnp.asarray(np.stack([p[3] for p in now]))
            ids = jnp.asarray(np.stack([p[4] for p in now]))
            sub = self._gather(shards)
            slots = np.asarray(sub.n_queries) % self.cfg.max_queries
            sub, dropped = insert_batched(sub, self.cfg, psi, radius, emb,
                                          ids, backend=backend or self.backend)
            self._scatter(shards, sub)
            self._claim_wave[shards, slots] = self.wave
            self._claim_alive[shards, slots] = True
            self.total_dropped += int(np.asarray(dropped).sum())
            promoted += len(now)
            pending = later
        self.n_promoted += promoted
        return promoted

    # ------------------------------------------------------------ result memo

    def memo_record(self, token, psi, ids, scores, radius: float) -> None:
        """Memoize one fresh retrieval's full (psi, top-k_c) result set."""
        psi = np.asarray(psi, np.float32)
        ids = np.asarray(ids)
        scores = np.asarray(scores, np.float32)
        if self._memo_psi is None:
            self._memo_psi = np.zeros((self.memo_size, psi.shape[-1]),
                                      np.float32)
            self._memo_ids = np.full((self.memo_size, ids.shape[-1]), -1,
                                     np.int64)
            self._memo_scores = np.full((self.memo_size, ids.shape[-1]),
                                        -np.inf, np.float32)
        slot = self._memo_n % self.memo_size
        self._memo_psi[slot] = psi
        self._memo_ids[slot] = ids
        self._memo_scores[slot] = scores
        self._memo_radius[slot] = radius
        self._memo_token[slot] = token
        self._memo_wave[slot] = self.wave
        self._memo_n += 1

    def memo_lookup(self, token, psi, *, allow_stale: bool = False):
        """Serve a near-duplicate query from another session's memoized
        result set, or None.

        Gates: cosine similarity >= ``memo_sim`` (the quality floor
        calibrated against the rank-overlap gate), the entry is from a
        DIFFERENT session (a same-session near-duplicate is the L1 tier's
        job), and the entry is fresher than ``ttl_waves``.
        Returns ``(ids, scores, claim_radius)`` where ``claim_radius`` is
        the triangle-corrected ``r_a - delta(psi_a, psi)`` (Eq. 3) the
        caller may soundly record as its own coverage claim.

        ``allow_stale`` is the stale-while-error mode the engine uses
        when the back end is fenced off: the TTL and other-session gates
        are waived (any written entry qualifies — stale results beat no
        results during an outage), but the similarity floor is NOT —
        staleness is about time, never about serving the wrong topic.
        Callers must treat a stale serve as degraded and never record
        its claim.
        """
        if self._memo_psi is None:
            return None
        psi = np.asarray(psi, np.float32)
        fresh = (self._memo_wave != _NEVER
                 if (allow_stale or self.ttl_waves is None)
                 else self.wave - self._memo_wave <= self.ttl_waves)
        other = np.array([t is not None and (allow_stale or t != token)
                          for t in self._memo_token])
        valid = np.logical_and(fresh, other)
        if not valid.any():
            return None
        sims = self._memo_psi @ psi  # unit-norm embeddings: dot == cosine
        sims = np.where(valid, sims, -np.inf)
        best = int(np.argmax(sims))
        if sims[best] < self.memo_sim:
            return None
        self.n_memo_served += 1
        if allow_stale:
            self.n_stale_served += 1
        delta = float(np.sqrt(max(2.0 - 2.0 * float(sims[best]), 0.0)))
        claim = float(self._memo_radius[best]) - delta
        return (self._memo_ids[best].copy(),
                self._memo_scores[best].copy(), claim)

    # ------------------------------------------------------------- inspection

    def contains(self, doc_ids) -> np.ndarray:
        """Membership of each id in ANY shard's cached documents (tests)."""
        cached = np.asarray(self.state.doc_ids).ravel()
        cached = cached[cached >= 0]
        return np.isin(np.asarray(doc_ids), cached)

    @property
    def n_docs(self) -> np.ndarray:
        return np.asarray(self.state.n_docs)

    def memory_bytes(self) -> int:
        s = self.state
        return sum(int(x.size) * x.dtype.itemsize for x in
                   (s.doc_emb, s.doc_ids, s.doc_stamp, s.q_emb, s.q_radius,
                    s.doc_scale, s.q_scale))
