"""Exact nearest-neighbor metric index — the FAISS ``IndexFlat`` analogue.

The back-end of the paper's architecture (Fig. 2): the whole collection's
transformed embeddings, answering ``NN(M, psi, k)`` queries exactly.

Three execution paths, all bit-compatible in ranking:
  * ``exact_nn``           — one-shot jnp reference (small corpora / oracle).
  * ``chunked_nn``         — ``lax.scan`` over corpus chunks with a running
                             top-k carry; bounds peak memory to O(B*chunk) and
                             mirrors the Pallas kernel's streaming structure.
  * ``kernels.knn``        — fused Pallas scan+top-k (imported lazily; used
                             when ``use_kernel=True``).

The distributed (sharded corpus) search lives in ``repro.dist.retrieval`` and
reuses ``streaming_topk`` per shard; ``MetricIndex(..., sharded=True)``
delegates to it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import embedding as emb

__all__ = ["SearchResult", "exact_nn", "chunked_nn", "masked_chunked_nn",
           "streaming_topk", "MetricIndex"]


class SearchResult(NamedTuple):
    scores: jax.Array     # (q, k) inner products, descending
    distances: jax.Array  # (q, k) Euclidean distances, ascending
    ids: jax.Array        # (q, k) int32 document ids


def _as_result(scores: jax.Array, ids: jax.Array) -> SearchResult:
    return SearchResult(scores, emb.distance_from_scores(scores), ids)


def exact_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int) -> SearchResult:
    """Reference exact k-NN: materializes the full (q, n) score matrix."""
    scores = emb.pairwise_scores(queries, docs)
    top_scores, top_idx = jax.lax.top_k(scores, k)
    return _as_result(top_scores, doc_ids[top_idx])


def streaming_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                   k: int, chunk: int, masked: bool = False):
    """Raw streaming top-k scan shared by ``chunked_nn``, the padded-corpus
    index path, and ``dist.retrieval``'s per-shard search.

    Scans corpus chunks with a running (scores, ids) carry; peak live memory
    is O(q*chunk + q*k).  ``n`` must be a multiple of ``chunk``.  When
    ``masked`` (static), rows with sentinel id < 0 score -inf, so padded
    corpora never win top-k.  Returns (scores (q, k), ids (q, k)).
    """
    n = docs.shape[0]
    assert n % chunk == 0, f"corpus size {n} not divisible by chunk {chunk}"
    docs_c = docs.reshape(n // chunk, chunk, docs.shape[1])
    ids_c = doc_ids.reshape(n // chunk, chunk)
    q = queries.shape[0]

    init = (jnp.full((q, k), -jnp.inf, queries.dtype),
            jnp.full((q, k), -1, jnp.int32))

    def step(carry, chunk_data):
        best_s, best_i = carry
        cd, ci = chunk_data
        scores = queries @ cd.T                                  # (q, chunk)
        if masked:
            scores = jnp.where(ci[None, :] < 0, -jnp.inf, scores)
        cand_s = jnp.concatenate([best_s, scores], axis=1)
        cand_i = jnp.concatenate([best_i, jnp.broadcast_to(ci, (q, chunk))], axis=1)
        top_s, top_pos = jax.lax.top_k(cand_s, k)
        top_i = jnp.take_along_axis(cand_i, top_pos, axis=1)
        return (top_s, top_i), None

    (best_s, best_i), _ = jax.lax.scan(step, init, (docs_c, ids_c))
    return best_s, best_i


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def chunked_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int,
               chunk: int = 4096) -> SearchResult:
    """Streaming exact k-NN over an unpadded corpus (see ``streaming_topk``)."""
    return _as_result(*streaming_topk(docs, doc_ids, queries, k, chunk))


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def masked_chunked_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                      k: int, chunk: int = 4096) -> SearchResult:
    """``chunked_nn`` over a sentinel-padded corpus (id < 0 rows masked)."""
    return _as_result(*streaming_topk(docs, doc_ids, queries, k, chunk,
                                      masked=True))


class MetricIndex:
    """Host-side handle over a (possibly padded) corpus of transformed embeddings.

    Accepts *raw* (l-dim) or *transformed* (l+1-dim, unit norm) embeddings.
    Raw input is transformed with Eq. 1 and the corpus max-norm M is kept so
    queries/documents added later share the same geometry.
    """

    def __init__(self, doc_emb, doc_ids=None, *, transformed: bool = False,
                 chunk: int = 4096, use_kernel: bool = False,
                 sharded: bool = False, mesh=None):
        doc_emb = jnp.asarray(doc_emb)
        if doc_ids is None:
            doc_ids = jnp.arange(doc_emb.shape[0], dtype=jnp.int32)
        doc_ids = jnp.asarray(doc_ids, jnp.int32)
        if transformed:
            self.max_norm = jnp.asarray(1.0, doc_emb.dtype)
            emb_t = doc_emb
        else:
            emb_t, self.max_norm = emb.transform_documents(doc_emb)
        self.dim = emb_t.shape[1]
        self.n_docs = int(emb_t.shape[0])
        self.chunk = int(min(chunk, max(8, self.n_docs)))
        # Pad to a chunk multiple with sentinels that can never win top-k:
        # zero vectors (score 0 with any query is beaten by any real doc on the
        # unit sphere only if scores > 0; use id -1 + -inf masking instead).
        pad = (-self.n_docs) % self.chunk
        if pad:
            emb_t = jnp.concatenate([emb_t, jnp.zeros((pad, self.dim), emb_t.dtype)])
            doc_ids = jnp.concatenate([doc_ids, jnp.full((pad,), -1, jnp.int32)])
        self._pad = pad
        self.doc_emb = emb_t
        self.doc_ids = doc_ids
        self.use_kernel = use_kernel
        self.sharded = sharded
        self.mesh = mesh
        if sharded:
            # Lay the corpus out across the mesh once at construction so
            # every search hits the shard_map fast path (no per-query pad
            # or host->mesh re-layout).
            from repro.dist import retrieval as dist_retrieval
            (self.doc_emb, self.doc_ids, self.mesh,
             self._shard_chunk) = dist_retrieval.shard_corpus(
                self.doc_emb, self.doc_ids, mesh=mesh, chunk=self.chunk)

    def transform_queries(self, psi: jax.Array) -> jax.Array:
        return emb.transform_queries(psi)

    def search(self, queries: jax.Array, k: int) -> SearchResult:
        """queries: (q, l+1) transformed embeddings."""
        if queries.ndim == 1:
            queries = queries[None]
        k = min(k, self.n_docs)
        if self.sharded:
            # Device-sharded corpus: per-shard streaming top-k under
            # shard_map, all-gather + merge (see repro.dist.retrieval).
            from repro.dist import retrieval as dist_retrieval
            return dist_retrieval.sharded_nn(self.doc_emb, self.doc_ids,
                                             queries, k, mesh=self.mesh,
                                             chunk=self._shard_chunk)
        if self.use_kernel:
            from repro.kernels.knn import ops as knn_ops
            scores, ids = knn_ops.knn_search(self.doc_emb[:self.n_docs],
                                             self.doc_ids[:self.n_docs], queries, k)
            res = _as_result(scores, ids)
        elif self._pad:
            # Masked search: padded sentinel rows carry id -1; over-fetch and
            # drop is wasteful, instead mask via score -inf on sentinel ids.
            res = masked_chunked_nn(self.doc_emb, self.doc_ids, queries, k,
                                    chunk=self.chunk)
        else:
            res = chunked_nn(self.doc_emb, self.doc_ids, queries, k, chunk=self.chunk)
        return res

    def __hash__(self):  # allow use as a static jit argument
        return id(self)

    def __eq__(self, other):
        return self is other
