"""Exact nearest-neighbor metric index — the FAISS ``IndexFlat`` analogue.

The back-end of the paper's architecture (Fig. 2): the whole collection's
transformed embeddings, answering ``NN(M, psi, k)`` queries exactly.

``scan_topk`` is THE corpus-scan contract: one signature, one sentinel
convention (id -1 rows masked out, -inf result positions carry id -1),
dispatched across the ``repro.kernels.dispatch`` tiers —

  * ``ref``       — ``streaming_topk``: a ``lax.scan`` over corpus chunks
                    with a running top-k carry (peak memory O(B*chunk));
                    the production path on CPU and the oracle in tests.
  * ``interpret`` / ``compiled`` — the fused Pallas scan+top-k
                    (``kernels.knn``) with its cross-tile merge on chip.

``MetricIndex.search``, the per-shard body of ``dist.retrieval.sharded_nn``,
and ``dist.retrieval.DeviceShard`` all route through it, so single-device
and device-sharded search share one scan implementation.  ``exact_nn``
remains the one-shot full-matrix oracle for small corpora.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import embedding as emb
from repro.core import quant
from repro.kernels import dispatch as kdispatch

__all__ = ["SearchResult", "exact_nn", "chunked_nn", "masked_chunked_nn",
           "streaming_topk", "scan_topk", "MetricIndex"]


class SearchResult(NamedTuple):
    scores: jax.Array     # (q, k) inner products, descending
    distances: jax.Array  # (q, k) Euclidean distances, ascending
    ids: jax.Array        # (q, k) int32 document ids


def _as_result(scores: jax.Array, ids: jax.Array) -> SearchResult:
    return SearchResult(scores, emb.distance_from_scores(scores), ids)


def exact_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int) -> SearchResult:
    """Reference exact k-NN: materializes the full (q, n) score matrix."""
    scores = emb.pairwise_scores(queries, docs)
    top_scores, top_idx = jax.lax.top_k(scores, k)
    return _as_result(top_scores, doc_ids[top_idx])


def streaming_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                   k: int, chunk: int, masked: bool = False,
                   scale: jax.Array | None = None, int8_dot: bool = False):
    """Raw streaming top-k scan shared by ``chunked_nn``, the padded-corpus
    index path, and ``dist.retrieval``'s per-shard search.

    Scans corpus chunks with a running (scores, ids) carry; peak live memory
    is O(q*chunk + q*k).  ``n`` must be a multiple of ``chunk``.  When
    ``masked`` (static), rows with sentinel id < 0 score -inf, so padded
    corpora never win top-k.  ``docs`` may be a quantized payload (bf16 /
    int8) with ``scale`` its (n,) f32 per-document score multiplier —
    dequantization is chunk-local (payload cast to f32, f32 dot, score-side
    scale), the same rule the Pallas tiers apply per tile, so peak memory
    stays O(q*chunk) and tiers agree.  ``int8_dot`` (int8 payloads only)
    switches to the native-narrow scoring rule of the kernel tiers: the
    queries quantize per-row to int8 once, each chunk's dot runs int8 x
    int8 with int32 accumulation, and both fp32 scales apply score-side in
    the kernels' association order — the ref tier of the int8-MXU path.
    Returns (scores (q, k), ids (q, k)).
    """
    n = docs.shape[0]
    assert n % chunk == 0, f"corpus size {n} not divisible by chunk {chunk}"
    int8_dot = bool(int8_dot) and docs.dtype == jnp.int8
    docs_c = docs.reshape(n // chunk, chunk, docs.shape[1])
    ids_c = doc_ids.reshape(n // chunk, chunk)
    scale_c = (None if scale is None else
               scale.astype(jnp.float32).reshape(n // chunk, chunk))
    q = queries.shape[0]
    queries = queries.astype(jnp.float32)
    if int8_dot:
        qq = quant.quantize(queries, "int8")
        q_payload, q_scale_col = qq.data, qq.scale[:, None]

    init = (jnp.full((q, k), -jnp.inf, queries.dtype),
            jnp.full((q, k), -1, jnp.int32))

    def step(carry, chunk_data):
        best_s, best_i = carry
        cd, ci, cs = chunk_data
        if int8_dot:
            acc = jax.lax.dot_general(
                q_payload, cd, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)                # (q, chunk)
            scores = acc.astype(jnp.float32) * q_scale_col
        else:
            scores = queries @ cd.astype(jnp.float32).T          # (q, chunk)
        scores = quant.scale_scores(scores, cs)
        if masked:
            scores = jnp.where(ci[None, :] < 0, -jnp.inf, scores)
        cand_s = jnp.concatenate([best_s, scores], axis=1)
        cand_i = jnp.concatenate([best_i, jnp.broadcast_to(ci, (q, chunk))], axis=1)
        top_s, top_pos = jax.lax.top_k(cand_s, k)
        top_i = jnp.take_along_axis(cand_i, top_pos, axis=1)
        return (top_s, top_i), None

    xs = (docs_c, ids_c, scale_c)
    if scale_c is None:
        xs = (docs_c, ids_c)
        step_fn = lambda c, x: step(c, (x[0], x[1], None))
    else:
        step_fn = step
    (best_s, best_i), _ = jax.lax.scan(step_fn, init, xs)
    return best_s, best_i


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def chunked_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int,
               chunk: int = 4096) -> SearchResult:
    """Streaming exact k-NN over an unpadded corpus (see ``streaming_topk``)."""
    return _as_result(*streaming_topk(docs, doc_ids, queries, k, chunk))


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def masked_chunked_nn(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array,
                      k: int, chunk: int = 4096) -> SearchResult:
    """``chunked_nn`` over a sentinel-padded corpus (id < 0 rows masked)."""
    return _as_result(*streaming_topk(docs, doc_ids, queries, k, chunk,
                                      masked=True))


def scan_topk(docs: jax.Array, doc_ids: jax.Array, queries: jax.Array, k: int,
              *, chunk: int = 4096, backend: str | None = None,
              tile_n: int | None = None, scale: jax.Array | None = None,
              int8_dot: bool | None = None):
    """The one corpus-scan contract (see module docstring).

    docs (N, D) with N a ``chunk`` multiple on the ref tier (the kernel
    tiers pad internally) — fp32, or a quantized payload (bf16 / int8,
    ``repro.core.quant``) with ``scale`` its (N,) f32 per-document score
    multiplier; doc_ids (N,) int32, -1 on sentinel rows; queries (B, D)
    f32.  Returns raw (scores (B, k), ids (B, k)) — descending scores,
    sentinel id -1 wherever the score is -inf — identical in ranking
    across tiers at a fixed dtype (rank equality vs the fp32 corpus is
    tolerance-bound; see tests/test_kernel_equivalence.py).  ``int8_dot``
    (None = the ``REPRO_INT8_DOT`` policy; int8 corpora only) switches
    every tier to the native int8-MXU scoring rule — tiers still agree
    with each other exactly, rankings vs fp32 are gated at the int8 floor.
    Trace-safe: usable inside jit and ``shard_map`` bodies (``backend``
    must then be a concrete tier, resolved outside).
    """
    be = kdispatch.resolve(backend)
    use_i8 = quant.resolve_int8_dot(int8_dot, docs.dtype)
    if be == "ref":
        return _streaming_topk_masked(docs, doc_ids, queries, scale, k=k,
                                      chunk=chunk, int8_dot=use_i8)
    from repro.kernels.knn import ops as knn_ops
    return knn_ops.knn_search(docs, doc_ids, queries, k, tile_n=tile_n,
                              backend=be, scale=scale, int8_dot=use_i8)


_streaming_topk_masked = jax.jit(
    lambda docs, doc_ids, queries, scale, *, k, chunk, int8_dot: (
        streaming_topk(docs, doc_ids, queries, k, chunk, masked=True,
                       scale=scale, int8_dot=int8_dot)),
    static_argnames=("k", "chunk", "int8_dot"))


class MetricIndex:
    """Host-side handle over a (possibly padded) corpus of transformed embeddings.

    Accepts *raw* (l-dim) or *transformed* (l+1-dim, unit norm) embeddings.
    Raw input is transformed with Eq. 1 and the corpus max-norm M is kept so
    queries/documents added later share the same geometry.

    ``use_kernel`` selects the scan tier: ``None`` (default) follows
    ``kernels.dispatch.default_backend()`` — the compiled Pallas kernel on
    TPU, the jnp streaming scan elsewhere; ``True`` pins the kernel
    (interpret mode off-TPU); ``False`` pins the jnp scan.

    ``dtype`` selects the corpus storage format (``repro.core.quant``):
    ``None`` follows ``quant.default_dtype()`` (the ``REPRO_CORPUS_DTYPE``
    policy, fp32 when unset); "bf16" / "int8" store the corpus quantized —
    2x / 4x more documents per HBM byte through the bandwidth-bound scan —
    and every tier dequantizes with the shared score-side-scale rule, so
    rankings stay tier-identical at the chosen dtype.
    """

    def __init__(self, doc_emb, doc_ids=None, *, transformed: bool = False,
                 chunk: int = 4096, use_kernel: bool | None = None,
                 sharded: bool = False, mesh=None, dtype: str | None = None,
                 int8_dot: bool | None = None):
        doc_emb = jnp.asarray(doc_emb)
        if doc_ids is None:
            doc_ids = jnp.arange(doc_emb.shape[0], dtype=jnp.int32)
        doc_ids = jnp.asarray(doc_ids, jnp.int32)
        if transformed:
            self.max_norm = jnp.asarray(1.0, doc_emb.dtype)
            emb_t = doc_emb
        else:
            emb_t, self.max_norm = emb.transform_documents(doc_emb)
        self.dim = emb_t.shape[1]
        self.n_docs = int(emb_t.shape[0])
        self.chunk = int(min(chunk, max(8, self.n_docs)))
        # Pad to a chunk multiple with sentinels that can never win top-k:
        # zero vectors (score 0 with any query is beaten by any real doc on the
        # unit sphere only if scores > 0; use id -1 + -inf masking instead).
        pad = (-self.n_docs) % self.chunk
        if pad:
            emb_t = jnp.concatenate([emb_t, jnp.zeros((pad, self.dim), emb_t.dtype)])
            doc_ids = jnp.concatenate([doc_ids, jnp.full((pad,), -1, jnp.int32)])
        self._pad = pad
        self.dtype = quant.resolve_dtype(dtype)
        qc = quant.quantize(emb_t, self.dtype)
        self.doc_emb = qc.data
        self.doc_scale = qc.scale
        self.doc_ids = doc_ids
        # int8-MXU-dot policy pinned at construction (None follows
        # REPRO_INT8_DOT) so every search over this index scores one way
        self.int8_dot = quant.resolve_int8_dot(int8_dot, self.doc_emb.dtype)
        self.use_kernel = use_kernel
        if use_kernel is None:
            self.backend = kdispatch.default_backend()
        elif use_kernel:
            self.backend = kdispatch.kernel_backend()
        else:
            self.backend = "ref"
        self.sharded = sharded
        self.mesh = mesh
        if sharded:
            # Lay the corpus out across the mesh once at construction so
            # every search hits the shard_map fast path (no per-query pad
            # or host->mesh re-layout).
            from repro.dist import retrieval as dist_retrieval
            (self.doc_emb, self.doc_ids, self.doc_scale, self.mesh,
             self._shard_chunk) = dist_retrieval.shard_corpus(
                self.doc_emb, self.doc_ids, scale=self.doc_scale, mesh=mesh,
                chunk=self.chunk)

    def transform_queries(self, psi: jax.Array) -> jax.Array:
        return emb.transform_queries(psi)

    def search(self, queries: jax.Array, k: int) -> SearchResult:
        """queries: (q, l+1) transformed embeddings."""
        if queries.ndim == 1:
            queries = queries[None]
        k = min(k, self.n_docs)
        if self.sharded:
            # Device-sharded corpus: the same scan per shard under
            # shard_map, all-gather + merge (see repro.dist.retrieval).
            from repro.dist import retrieval as dist_retrieval
            return dist_retrieval.sharded_nn(self.doc_emb, self.doc_ids,
                                             queries, k, mesh=self.mesh,
                                             chunk=self._shard_chunk,
                                             backend=self.backend,
                                             scale=self.doc_scale,
                                             int8_dot=self.int8_dot)
        return _as_result(*scan_topk(self.doc_emb, self.doc_ids, queries, k,
                                     chunk=self.chunk, backend=self.backend,
                                     scale=self.doc_scale,
                                     int8_dot=self.int8_dot))

    def cluster(self, n_clusters: int = 64, *, iters: int = 10, seed: int = 0,
                max_width: int = 256, backend: str | None = None, path=None):
        """Build (and memoize) a topical ``ClusterIndex`` over this corpus.

        Parameters mirror ``repro.core.cluster.build_cluster_index``.
        ``path`` persists the artifact: an existing ``.npz`` at ``path`` is
        loaded instead of rebuilding, otherwise the fresh index is saved
        there.  Builds are memoized per parameter tuple — the corpus is
        immutable after construction, so a rebuild can never differ.
        """
        import os

        from repro.core.cluster import ClusterIndex, build_cluster_index
        key = (int(n_clusters), int(iters), int(seed), int(max_width), backend)
        memo = getattr(self, "_clusters", None)
        if memo is None:
            memo = self._clusters = {}
        if key not in memo:
            if path is not None and os.path.exists(path):
                memo[key] = ClusterIndex.load(path)
            else:
                memo[key] = build_cluster_index(
                    self, n_clusters, iters=iters, seed=seed,
                    max_width=max_width, backend=backend)
                if path is not None:
                    memo[key].save(path)
        return memo[key]

    def dequantized(self) -> jax.Array:
        """f32 view of the (padded) transformed corpus — the exact values
        every scan tier scores against.  Host-side tooling (benchmark shard
        construction, engine doc-embedding lookups) should use this rather
        than ``doc_emb``, whose dtype follows the storage policy.  The view
        is memoized: the corpus is immutable after construction."""
        if getattr(self, "_dequant", None) is None:
            self._dequant = quant.dequantize(
                quant.QuantizedCorpus(self.doc_emb, self.doc_scale,
                                      self.dtype))
        return self._dequant

    def __hash__(self):  # allow use as a static jit argument
        return id(self)

    def __eq__(self, other):
        return self is other
