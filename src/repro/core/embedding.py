"""MIPS -> Euclidean-NN embedding transform (paper Eq. 1).

STAR-style encoders are fine-tuned for maximum-inner-product search.  To use
metric-space machinery (hyperball containment, the LowQuality test) the paper
maps R^l embeddings onto the unit sphere in R^{l+1} via the asymmetric
Neyshabur-Srebro / Bachrach transform:

    psi_bar = [ psi / ||psi||          , 0 ]                  (queries)
    phi_bar = [ phi / M , sqrt(1 - ||phi||^2 / M^2) ]         (documents)

with M = max_i ||phi_i||.  Then  argmax <psi, phi>  ==  argmin ||psi_bar - phi_bar||.

All downstream code operates on *transformed* embeddings: unit-norm vectors in
R^{l+1}, where squared Euclidean distance is 2 - 2<a, b>.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "transform_documents",
    "transform_queries",
    "distance_from_scores",
    "pairwise_scores",
    "pairwise_distances",
]


def transform_documents(phi: jax.Array, max_norm: float | jax.Array | None = None):
    """Apply the document side of Eq. 1; returns (phi_bar, M).

    phi: (n, l) raw document embeddings.
    max_norm: M. If None, computed from this batch (the whole collection must
      share a single M — compute it once over the corpus and pass it in when
      transforming incremental batches).
    """
    norms = jnp.linalg.norm(phi, axis=-1)
    m = jnp.max(norms) if max_norm is None else jnp.asarray(max_norm, phi.dtype)
    scaled = phi / m
    # Guard tiny negative values from rounding before sqrt.
    extra = jnp.sqrt(jnp.clip(1.0 - jnp.sum(scaled * scaled, axis=-1), 0.0, None))
    return jnp.concatenate([scaled, extra[..., None]], axis=-1), m


def transform_queries(psi: jax.Array) -> jax.Array:
    """Apply the query side of Eq. 1: L2-normalize and append a zero."""
    normed = psi / jnp.linalg.norm(psi, axis=-1, keepdims=True)
    zero = jnp.zeros(normed.shape[:-1] + (1,), normed.dtype)
    return jnp.concatenate([normed, zero], axis=-1)


def distance_from_scores(scores: jax.Array) -> jax.Array:
    """Euclidean distance between unit vectors from their inner product.

    ||a - b||^2 = 2 - 2<a,b>  for  ||a|| = ||b|| = 1.
    """
    return jnp.sqrt(jnp.clip(2.0 - 2.0 * scores, 0.0, None))


def pairwise_scores(queries: jax.Array, docs: jax.Array) -> jax.Array:
    """(q, l+1) x (n, l+1) -> (q, n) inner-product scores."""
    return queries @ docs.T


def pairwise_distances(queries: jax.Array, docs: jax.Array) -> jax.Array:
    """(q, l+1) x (n, l+1) -> (q, n) Euclidean distances (unit-norm inputs)."""
    return distance_from_scores(pairwise_scores(queries, docs))
