"""Optimizers (optax is not installed offline — hand-rolled, pytree-native).

* AdamW — default for <=10B-class models.
* Adafactor — factored second moments; the only optimizer whose state fits
  per-device HBM for the 123B/671B configs at 256 chips (see DESIGN.md §6).
  Supports bf16 parameter training with stochastic rounding.

Optimizer state pytrees mirror the parameter shardings, so ZeRO-style full
state sharding falls out of the param PartitionSpecs for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params) -> (new_params, new_state)
    # (param_shapes_tree, param_spec_tree) -> OptState-shaped PartitionSpec tree
    state_spec: Callable = None


def _schedule(lr: float, warmup: int, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    return lr * warm


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          warmup: int = 100, grad_clip: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": jax.tree.map(zeros, params),
                         "v": jax.tree.map(zeros, params)})

    def update(grads, state, params):
        step = state.step + 1
        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr_t = _schedule(lr, warmup, step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.inner["m"], state.inner["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step, {"m": new_m, "v": new_v})

    def state_spec(param_shapes, param_specs):
        from jax.sharding import PartitionSpec as P
        return OptState(P(), {"m": param_specs, "v": param_specs})

    return Optimizer(init, update, state_spec)


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, warmup: int = 100,
              stochastic_rounding: bool = True, seed: int = 0) -> Optimizer:
    """Factored Adafactor (no momentum): O(rows + cols) state for matrices."""

    def _factored(shape):
        # factor only genuine matrices (both trailing dims substantial);
        # layer-stacked vectors like (L, d) norms stay un-factored so the
        # state never couples across the stack axis (required for the
        # slice-at-a-time update below)
        return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32), {"v": jax.tree.map(
            st, params, is_leaf=lambda x: isinstance(x, jax.Array))})

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay
        lr_t = _schedule(lr, warmup, step)
        key = jax.random.fold_in(jax.random.key(seed), step)

        def upd_slice(leaf_key, p, g, v):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)   # (..., R)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)   # (..., C)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(r)[..., :, None] \
                      * jax.lax.rsqrt(jnp.maximum(vc, eps))[..., None, :]
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(vv)
                new_v = {"v": vv}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p32 = p.astype(jnp.float32) - lr_t * u
            if p.dtype == jnp.bfloat16 and stochastic_rounding:
                new_p = _stochastic_round_bf16(new_p32, leaf_key)
            else:
                new_p = new_p32.astype(p.dtype)
            return new_p, new_v

        def upd(i, p, g, v):
            leaf_key = jax.random.fold_in(key, i)
            if p.ndim >= 3:
                # layer-stacked leaf: fori_loop one layer slice at a time so
                # f32/u32 optimizer transients (incl. stochastic-rounding
                # noise) are per-layer, not whole-stack (whole-stack u32
                # noise alone was 38 GiB/device on the 671B cell).
                # dynamic_slice reads + in-place dynamic_update keep the
                # stack buffers aliased (lax.map would copy the xs).
                def body(j, carry):
                    out_p, out_v = carry
                    ps = jax.lax.dynamic_index_in_dim(p, j, keepdims=False)
                    gs = jax.lax.dynamic_index_in_dim(g, j, keepdims=False)
                    vs = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, j, keepdims=False), v)
                    np_s, nv_s = upd_slice(jax.random.fold_in(leaf_key, j),
                                           ps, gs, vs)
                    out_p = jax.lax.dynamic_update_index_in_dim(
                        out_p, np_s.astype(out_p.dtype), j, 0)
                    out_v = jax.tree.map(
                        lambda a, b: jax.lax.dynamic_update_index_in_dim(
                            a, b, j, 0), out_v, nv_s)
                    return out_p, out_v
                return jax.lax.fori_loop(0, p.shape[0], body, (p, v))
            return upd_slice(leaf_key, p, g, v)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state.inner["v"])
        outs = [upd(i, p, g, v)
                for i, (p, g, v) in enumerate(zip(flat_p, flat_g, flat_v))]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_v = treedef.unflatten([o[1] for o in outs])
        return new_params, OptState(step, {"v": new_v})

    def state_spec(param_shapes, param_specs):
        from jax.sharding import PartitionSpec as P

        def st(p, spec):
            full = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
            if _factored(p.shape):
                return {"vr": P(*full[:-1]), "vc": P(*(full[:-2] + full[-1:]))}
            return {"v": P(*full)}

        v = jax.tree.map(st, param_shapes, param_specs,
                         is_leaf=lambda x: hasattr(x, "shape"))
        return OptState(P(), {"v": v})

    return Optimizer(init, update, state_spec)


def _stochastic_round_bf16(x32: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased f32 -> bf16 rounding: add uniform noise below the bf16 LSB."""
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    noise = jax.random.randint(key, x32.shape, 0, 1 << 16, jnp.uint32)
    return jax.lax.bitcast_convert_type(
        (bits + noise) & jnp.uint32(0xFFFF0000), jnp.float32).astype(jnp.bfloat16)


def _sum_sq(leaf) -> jax.Array:
    """Sum of squares in f32. Layer-stacked leaves are reduced one slice at
    a time (fori_loop) so the f32 upcast transient is per-layer, and the
    sequential dependency chain keeps only one copy live."""
    if leaf.ndim >= 3:
        def body(i, acc):
            s = jax.lax.dynamic_index_in_dim(leaf, i, keepdims=False)
            s = s.astype(jnp.float32)
            return acc + jnp.sum(s * s)
        return jax.lax.fori_loop(0, leaf.shape[0], body,
                                 jnp.zeros((), jnp.float32))
    x = leaf.astype(jnp.float32)
    return jnp.sum(x * x)


def global_norm(tree) -> jax.Array:
    total = jnp.zeros((), jnp.float32)
    for l in jax.tree.leaves(tree):      # chained adds => sequenced, 1 live
        total = total + _sum_sq(l)
    return jnp.sqrt(total)
