"""Train-step factories: loss, grad accumulation, optimizer application.

``make_lm_train_step`` is what the dry-run lowers for the 5 LM architectures
(``train_4k``).  Grad accumulation scans microbatches with a donated f32
accumulator; remat policy comes from the model config.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tf
from repro.train.optimizer import Optimizer, global_norm


def lm_loss_fn(params, batch, cfg: tf.TransformerConfig, remat: str = "full"):
    logits, aux, hidden, _ = tf.forward(params, batch["tokens"], cfg, remat=remat)
    loss = cm.cross_entropy(logits, batch["labels"])
    total = loss + aux
    if cfg.mtp:
        m_logits = tf.mtp_logits(params, batch["tokens"], hidden, cfg)
        # MTP predicts token t+2: labels shifted one more step
        mtp_labels = jnp.pad(batch["labels"][:, 1:], ((0, 0), (0, 1)),
                             constant_values=-1)
        total = total + cfg.mtp_weight * cm.cross_entropy(m_logits, mtp_labels)
    return total, {"ce": loss, "aux": aux}


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    accum_steps: int = 1, unroll_accum: bool = False,
                    grad_shardings=None, accum_dtype=jnp.float32):
    """loss_fn(params, microbatch) -> (scalar, metrics dict).

    Returns train_step(state, batch) -> (state, metrics); ``state`` is
    {"params": ..., "opt": OptState}. With accum_steps > 1, the leading batch
    axis is split into microbatches scanned with an f32 grad accumulator —
    activation temps scale as 1/accum_steps (the lever that fits the 123B /
    671B train cells in 16 GiB HBM).  ``unroll_accum`` unrolls the
    microbatch scan so calibration cost-counting sees every trip.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)
            # (p * 0) instead of zeros(): the accumulator DERIVES from the
            # param so SPMD propagates the param sharding — plain zeros were
            # materialized replicated (measured +10 GiB/device on deepseek).
            # accum_dtype=bf16 halves the persistent accumulator: required to
            # fit 671B-class training on a single 256-chip pod (f32 fits at
            # 512 chips; see EXPERIMENTS.md §Dry-run).
            acc0 = jax.tree.map(lambda p: (p * 0).astype(accum_dtype), params)

            def _pin(tree):
                # keep grads reduce-scattered onto the param shardings inside
                # the loop — without this XLA all-gathers the FSDP axis of
                # every grad (measured +8 GiB/device on the 671B cell)
                if grad_shardings is None:
                    return tree
                return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                                    grad_shardings)

            acc0 = _pin(acc0)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                g = _pin(g)
                acc = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), acc, g))
                return acc, (l, m)

            grads, (losses, metricses) = jax.lax.scan(
                body, acc0, micro, unroll=accum_steps if unroll_accum else 1)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)

        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads))
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_lm_train_step(cfg: tf.TransformerConfig, optimizer: Optimizer,
                       accum_steps: int = 1, remat: str = "full",
                       grad_shardings=None, accum_dtype=jnp.float32):
    loss = functools.partial(lm_loss_fn, cfg=cfg, remat=remat)
    return make_train_step(lambda p, b: loss(p, b), optimizer, accum_steps,
                           unroll_accum=cfg.layer_unroll,
                           grad_shardings=grad_shardings,
                           accum_dtype=accum_dtype)
