"""The paper's own encoder: STAR [arXiv:2108.xxxxx / SIGIR'21] is a
BERT-base bi-encoder (12L, d768, 12H) producing 768-d embeddings, +1 dim
from the Eq. 1 transform. Weights are unavailable offline; this config
gives the CACHE pipeline a faithfully-shaped encoder backbone."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "star-encoder"
FAMILY = "lm"
OPTIMIZER = "adamw"
TRAIN_ACCUM_STEPS = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_head=64, d_ff=3072, vocab_size=30522,
        tie_embeddings=True, dtype=jnp.float32,
        q_chunk=128, kv_chunk=128,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_head=8, d_ff=64, vocab_size=256,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )
