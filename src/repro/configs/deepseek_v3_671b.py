"""deepseek-v3-671b [arXiv:2412.19437]: 61L d7168, MLA (128 heads), MoE
256 routed experts top-8 + 1 shared, first 3 layers dense (d_ff 18432),
MTP depth 1, vocab 129280."""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import MLAConfig, TransformerConfig

ARCH_ID = "deepseek-v3-671b"
FAMILY = "lm"
OPTIMIZER = "adafactor"         # Adam state does not fit 256 v5e chips (§6)
TRAIN_ACCUM_STEPS = 32


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,                       # the 3 dense layers
        vocab_size=129280,
        attention="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                      d_ff_shared=2048, capacity_factor=1.25),
        n_dense_layers=3,
        mtp=True,
        tie_embeddings=False,
        rope_theta=1e4,
        dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=2048,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=8,
        n_kv_heads=8, d_ff=128, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                      qk_rope_dim=4, v_head_dim=8),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                      d_ff_shared=32),
        n_dense_layers=1, mtp=True, tie_embeddings=False,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )


# bf16 grad accumulation: the f32 accumulator alone is 10.5 GiB/chip at 256
# chips (671e9 * 4 / 256); bf16 halves it. f32 accumulation fits on the
# 512-chip multi-pod mesh — see EXPERIMENTS.md §Dry-run.
import jax.numpy as _jnp
ACCUM_DTYPE = _jnp.bfloat16
