"""gemma2-9b [arXiv:2408.00118]: 42L d3584 16H GQA kv=8 d_head 256,
GeGLU d_ff 14336, vocab 256000; alternating local(4096)/global attention,
attn logit softcap 50, final softcap 30, pre+post RMSNorm (zero-centered),
embeddings scaled by sqrt(d), tied head."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma2-9b"
FAMILY = "lm"
OPTIMIZER = "adamw"
TRAIN_ACCUM_STEPS = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_head=256, d_ff=14336, vocab_size=256000,
        window=4096, layer_pattern="lg",
        attn_softcap=50.0, final_softcap=30.0,
        use_post_norm=True, zero_centered_norm=True, embed_scale=True,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=2048,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab_size=512,
        window=8, layer_pattern="lg", attn_softcap=50.0, final_softcap=30.0,
        use_post_norm=True, zero_centered_norm=True, embed_scale=True,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )
