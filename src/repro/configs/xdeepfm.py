"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed 10, CIN 200-200-200,
DNN 400-400, order-1 linear term."""

import jax.numpy as jnp

from repro.models.recsys import XDeepFMConfig

ARCH_ID = "xdeepfm"
FAMILY = "recsys"
OPTIMIZER = "adamw"


def full_config() -> XDeepFMConfig:
    return XDeepFMConfig(name=ARCH_ID, n_sparse=39, embed_dim=10,
                         vocab=1_048_576, cin_layers=(200, 200, 200),
                         mlp=(400, 400), dtype=jnp.float32)


def smoke_config() -> XDeepFMConfig:
    return XDeepFMConfig(name=ARCH_ID + "-smoke", n_sparse=6, embed_dim=4,
                         vocab=500, cin_layers=(8, 8), mlp=(16,),
                         dtype=jnp.float32)
