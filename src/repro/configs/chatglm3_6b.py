"""chatglm3-6b [arXiv:2406.12793]: 28L d4096 32H GQA kv=2, SwiGLU d_ff
13696, vocab 65024, partial ("2d") interleaved RoPE over half the head dim."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "chatglm3-6b"
FAMILY = "lm"
OPTIMIZER = "adamw"
TRAIN_ACCUM_STEPS = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_head=128, d_ff=13696, vocab_size=65024,
        rotary_frac=0.5, rope_interleaved=True,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=2048,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab_size=512,
        rotary_frac=0.5, rope_interleaved=True, tie_embeddings=False,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )
