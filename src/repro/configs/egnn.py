"""egnn [arXiv:2102.09844]: 4 layers, d_hidden 64, E(n)-equivariant.

The paper's CACHE technique is INAPPLICABLE to this architecture (no
nearest-neighbor retrieval step in its forward path) — implemented without
it per DESIGN.md §Arch-applicability."""

import jax.numpy as jnp

from repro.models.egnn import EGNNConfig

ARCH_ID = "egnn"
FAMILY = "gnn"
OPTIMIZER = "adamw"

# per-shape input geometry (from the assignment)
SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          kind="full"),
    "minibatch_lg": dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, kind="mini"),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                         kind="full"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                     kind="batched"),
}


def full_config(d_feat: int = 1433, readout: str = "node") -> EGNNConfig:
    return EGNNConfig(name=ARCH_ID, n_layers=4, d_hidden=64, d_feat_in=d_feat,
                      n_classes=8, readout=readout, dtype=jnp.float32)


def smoke_config() -> EGNNConfig:
    return EGNNConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16,
                      d_feat_in=8, n_classes=4, dtype=jnp.float32)
