"""bert4rec [arXiv:1904.06690]: bidirectional sequence recommender,
embed 64, 2 blocks, 2 heads, seq 200. Encoder-only: no decode shapes."""

import jax.numpy as jnp

from repro.models.recsys import SeqRecConfig

ARCH_ID = "bert4rec"
FAMILY = "recsys"
OPTIMIZER = "adamw"


def full_config() -> SeqRecConfig:
    return SeqRecConfig(name=ARCH_ID, vocab=1_048_576, max_len=200,
                        embed_dim=64, n_blocks=2, n_heads=2, causal=False,
                        dtype=jnp.float32)


def smoke_config() -> SeqRecConfig:
    return SeqRecConfig(name=ARCH_ID + "-smoke", vocab=200, max_len=16,
                        embed_dim=16, n_blocks=2, n_heads=2, causal=False,
                        dtype=jnp.float32)
