"""sasrec [arXiv:1808.09781]: causal self-attention sequence recommender,
embed 50, 2 blocks, 1 head, seq 50. Item vocab 1e6 (= retrieval candidates)."""

import jax.numpy as jnp

from repro.models.recsys import SeqRecConfig

ARCH_ID = "sasrec"
FAMILY = "recsys"
OPTIMIZER = "adamw"


def full_config() -> SeqRecConfig:
    return SeqRecConfig(name=ARCH_ID, vocab=1_048_576, max_len=50,
                        embed_dim=50, n_blocks=2, n_heads=1, causal=True,
                        dtype=jnp.float32)


def smoke_config() -> SeqRecConfig:
    return SeqRecConfig(name=ARCH_ID + "-smoke", vocab=200, max_len=12,
                        embed_dim=16, n_blocks=2, n_heads=1, causal=True,
                        dtype=jnp.float32)
