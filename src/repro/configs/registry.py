"""Architecture registry: --arch <id> resolution for launchers and tests."""

from __future__ import annotations

import importlib

_MODULES = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llama4-scout-17b-16e": "repro.configs.llama4_scout_17b_a16e",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "egnn": "repro.configs.egnn",
    "bert4rec": "repro.configs.bert4rec",
    "xdeepfm": "repro.configs.xdeepfm",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "sasrec": "repro.configs.sasrec",
    # the paper's own encoder backbone (extra, not one of the 40 cells)
    "star-encoder": "repro.configs.star_encoder",
}

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

ASSIGNED = [a for a in _MODULES if a != "star-encoder"]


def get(arch_id: str):
    return importlib.import_module(_MODULES[arch_id])


def shapes_for(arch_id: str) -> tuple:
    fam = get(arch_id).FAMILY
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[fam]


def all_cells():
    """The 40 assigned (arch x shape) cells."""
    return [(a, s) for a in ASSIGNED for s in shapes_for(a)]
