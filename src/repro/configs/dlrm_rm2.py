"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse features, embed 64,
bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.
Tables: 26 x 1e6 rows (row-sharded over the whole mesh)."""

import jax.numpy as jnp

from repro.models.recsys import DLRMConfig

ARCH_ID = "dlrm-rm2"
FAMILY = "recsys"
OPTIMIZER = "adamw"


def full_config() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID, n_dense=13, n_sparse=26, embed_dim=64,
                      vocab=1_048_576, multi_hot=1,
                      bot_mlp=(13, 512, 256, 64),
                      top_mlp_hidden=(512, 512, 256, 1),
                      dtype=jnp.float32)


def smoke_config() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID + "-smoke", n_dense=13, n_sparse=4,
                      embed_dim=8, vocab=1000, multi_hot=2,
                      bot_mlp=(13, 16, 8), top_mlp_hidden=(16, 1),
                      dtype=jnp.float32)
