"""llama4-scout-17b-16e [hf:meta-llama/Llama-4-Scout-17B-16E]: 48L d5120,
40H GQA kv=8, MoE 16 experts top-1 + shared expert (d_ff 8192), vocab
202048.  Text backbone only (the early-fusion vision frontend is a stub:
input_specs provide token ids / precomputed patch embeddings)."""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "llama4-scout-17b-16e"
FAMILY = "lm"
OPTIMIZER = "adafactor"
TRAIN_ACCUM_STEPS = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_head=128, d_ff=8192, vocab_size=202048,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1,
                      d_ff_shared=8192, capacity_factor=1.5,
                      norm_topk=False),
        n_dense_layers=0,
        rope_theta=5e5,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=2048,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=96, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff=96, n_shared=1,
                      d_ff_shared=96, norm_topk=False),
        tie_embeddings=False, dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )
