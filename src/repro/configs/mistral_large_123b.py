"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]: dense 88L
d12288 96H GQA kv=8 d_head 128, SwiGLU d_ff 28672, vocab 32768."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "mistral-large-123b"
FAMILY = "lm"
OPTIMIZER = "adamw"             # 14 B/param state / 256 chips = 6.7 GB: fits
TRAIN_ACCUM_STEPS = 8


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
        d_head=128, d_ff=28672, vocab_size=32768,
        rope_theta=1e6,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        q_chunk=1024, kv_chunk=2048,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=192, vocab_size=512,
        tie_embeddings=False, dtype=jnp.float32, q_chunk=16, kv_chunk=16,
    )
