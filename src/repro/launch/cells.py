"""(architecture x input-shape) cell builders for the multi-pod dry-run.

``build_cell(arch, shape, mesh)`` returns everything needed to lower +
compile the cell without allocating a single parameter: step fn, input
ShapeDtypeStructs, in/out shardings, activation-sharding rules, and the
analytic MODEL_FLOPS for the roofline's usefulness ratio.

Shape semantics (per the assignment):
  LM:     train_4k -> train_step; prefill_32k -> prefill;
          decode_32k / long_500k -> serve_step (1 new token vs. KV cache).
  GNN:    full-batch / sampled-block / batched-small train steps.
  RecSys: train_batch -> train_step; serve_* -> forward scoring;
          retrieval_cand -> query-tower + sharded MIPS top-k.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist.api import data_axes
from repro.dist import sharding as shd
from repro.dist.retrieval import make_batched_scorer
from repro.models import common as cm
from repro.models import egnn as egnn_mod
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train import optimizer as opt_mod
from repro.train.step import make_lm_train_step, make_train_step

# ---------------------------------------------------------------- helpers

LM_SHAPE_DEFS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="long", seq=524288, batch=1),
}
RECSYS_SHAPE_DEFS = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


@dataclasses.dataclass
class BuiltCell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    rules: dict
    meta: dict


def make_optimizer(name: str) -> opt_mod.Optimizer:
    return {"adamw": opt_mod.adamw, "adafactor": opt_mod.adafactor}[name]()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


# ------------------------------------------------------------------- LM

def _lm_state(mod, cfg, mesh):
    opt = make_optimizer(mod.OPTIMIZER)
    params_shapes = jax.eval_shape(
        lambda: tf.init_params(jax.random.key(0), cfg))
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    p_specs = shd.param_specs(params_shapes, mesh)
    o_specs = opt.state_spec(params_shapes, p_specs)
    state_shapes = {"params": params_shapes, "opt": opt_shapes}
    state_specs = {"params": p_specs, "opt": o_specs}
    return opt, state_shapes, state_specs, params_shapes, p_specs


def _lm_flops(cfg, params_shapes, tokens: int, fwd_only: bool) -> float:
    n_active = tf.active_param_count(cfg, params_shapes)
    return (2 if fwd_only else 6) * n_active * tokens


def build_lm_cell(arch: str, shape: str, mesh: Mesh,
                  cfg_override=None) -> BuiltCell:
    mod = registry.get(arch)
    cfg = cfg_override if cfg_override is not None else mod.full_config()
    d = LM_SHAPE_DEFS[shape]
    dp = tuple(data_axes(mesh))
    rules = shd.lm_activation_rules(mesh, cfg, d["kind"])
    opt, state_shapes, state_specs, params_shapes, p_specs = _lm_state(mod, cfg, mesh)
    b, s = d["batch"], d["seq"]

    if d["kind"] == "train":
        accum = getattr(mod, "TRAIN_ACCUM_STEPS", 1)
        accum_dtype = getattr(mod, "ACCUM_DTYPE", jnp.float32)
        step = make_lm_train_step(cfg, opt, accum_steps=accum,
                                  grad_shardings=_named(mesh, p_specs),
                                  accum_dtype=accum_dtype)
        batch_shapes = {"tokens": _sds((b, s), jnp.int32),
                        "labels": _sds((b, s), jnp.int32)}
        batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        return BuiltCell(
            arch, shape, "train", step,
            (state_shapes, batch_shapes),
            (_named(mesh, state_specs), _named(mesh, batch_specs)),
            (_named(mesh, state_specs), None), rules,
            {"model_flops": _lm_flops(cfg, params_shapes, b * s, False),
             "tokens": b * s})

    if d["kind"] == "prefill":
        def prefill(params, tokens):
            logits, _aux, _h, caches = tf.forward(
                params, tokens, cfg, return_kv=True, kv_len=s, remat="full")
            return logits, caches
        batch_shape = _sds((b, s), jnp.int32)
        return BuiltCell(
            arch, shape, "prefill", prefill,
            (params_shapes, batch_shape),
            (_named(mesh, p_specs), NamedSharding(mesh, P(dp, None))),
            None, rules,
            {"model_flops": _lm_flops(cfg, params_shapes, b * s, True),
             "tokens": b * s})

    # decode / long: one new token against a KV cache of length `seq`
    caches_shapes = jax.eval_shape(lambda: tf.init_kv_caches(cfg, b, s))
    if cfg.attention == "mla":
        cache_spec_one = (P(*((None,) + tuple(rules["mla_cache"]))),
                          P(*((None,) + tuple(rules["mla_cache_r"]))))
    else:
        cache_spec_one = (P(*((None,) + tuple(rules["kv_cache"]))),) * 2
    caches_specs = [cache_spec_one for _ in cfg.layer_groups()]
    token_spec = P(dp) if b % max(1, _axis_prod(mesh, dp)) == 0 else P()

    def serve_step(params, token, caches, cur_len):
        return tf.decode_step(params, token, caches, cur_len, cfg)

    args = (params_shapes, _sds((b,), jnp.int32), caches_shapes,
            _sds((), jnp.int32))
    in_sh = (_named(mesh, p_specs), NamedSharding(mesh, token_spec),
             _named(mesh, caches_specs), NamedSharding(mesh, P()))
    out_sh = (None, _named(mesh, caches_specs))
    return BuiltCell(
        arch, shape, d["kind"], serve_step, args, in_sh, out_sh, rules,
        {"model_flops": _lm_flops(cfg, params_shapes, b, True),
         "tokens": b, "kv_len": s})


def _axis_prod(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


# ------------------------------------------------------------------- GNN

def build_gnn_cell(arch: str, shape: str, mesh: Mesh) -> BuiltCell:
    mod = registry.get(arch)
    geom = mod.SHAPES[shape]
    dp = tuple(data_axes(mesh))
    every = dp + ("model",)
    n_dev = _axis_prod(mesh, every)
    # nodes sharded over the whole mesh too: the per-layer gather of h at
    # edge endpoints becomes the (realistic) all-gather collective of
    # distributed full-graph training.
    rules = {"edges": P(every, None), "nodes": P(every, None)}

    if geom["kind"] == "batched":
        n_nodes = geom["n_nodes"] * geom["batch"]
        n_edges = _pad_to(geom["n_edges"] * geom["batch"], n_dev)
        readout, n_out = "graph", geom["batch"]
        d_feat = geom["d_feat"]
    elif geom["kind"] == "mini":
        seeds = geom["batch_nodes"]
        f1, f2 = geom["fanout"]
        n_edges = _pad_to(seeds * f1 + seeds * f1 * f2, n_dev)
        n_nodes = _pad_to(seeds * (1 + f1 + f1 * f2), n_dev)
        readout, n_out = "node", n_nodes
        d_feat = geom["d_feat"]
    else:
        n_nodes = geom["n_nodes"]
        n_edges = _pad_to(geom["n_edges"], n_dev)
        readout, n_out = "node", n_nodes
        d_feat = geom["d_feat"]

    cfg = mod.full_config(d_feat=d_feat, readout=readout)
    opt = make_optimizer(mod.OPTIMIZER)
    params_shapes = jax.eval_shape(
        lambda: egnn_mod.init_params(jax.random.key(0), cfg))
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    p_specs = jax.tree.map(lambda _: P(), params_shapes)   # tiny: replicate
    state_specs = {"params": p_specs,
                   "opt": opt.state_spec(params_shapes, p_specs)}
    state_shapes = {"params": params_shapes, "opt": opt_shapes}

    n_graphs = geom.get("batch")

    def loss_fn(params, batch):
        logits, _ = egnn_mod.forward(
            params, batch["feat"], batch["coords"], batch["edge_index"], cfg,
            graph_ids=batch.get("graph_ids"), n_graphs=n_graphs)
        return cm.cross_entropy(logits[None], batch["labels"][None]), {}

    step = make_train_step(loss_fn, opt)
    batch_shapes = {"feat": _sds((n_nodes, d_feat), jnp.float32),
                    "coords": _sds((n_nodes, 3), jnp.float32),
                    "edge_index": _sds((2, n_edges), jnp.int32),
                    "labels": _sds((n_out,), jnp.int32)}
    batch_specs = {"feat": P(None, None), "coords": P(None, None),
                   "edge_index": P(None, every), "labels": P(None)}
    if geom["kind"] == "batched":
        batch_shapes["graph_ids"] = _sds((n_nodes,), jnp.int32)
        batch_specs["graph_ids"] = P(None)

    # message-passing flops: per edge per layer ~ 2 * (phi_e + phi_x) matmuls
    dh = cfg.d_hidden
    per_edge = 2 * ((2 * dh + 1) * dh + dh * dh + dh * dh + dh)
    per_node = 2 * (2 * dh * dh + dh * dh)
    mf = 3 * cfg.n_layers * (n_edges * per_edge + n_nodes * per_node)

    return BuiltCell(
        arch, shape, "train", step,
        ({"params": params_shapes, "opt": state_shapes["opt"]}, batch_shapes),
        (_named(mesh, state_specs), _named(mesh, batch_specs)),
        (_named(mesh, state_specs), None), rules,
        {"model_flops": float(mf), "edges": n_edges, "nodes": n_nodes})


# ---------------------------------------------------------------- RecSys

def _recsys_model(arch: str, cfg):
    if arch == "dlrm-rm2":
        init = functools.partial(rs.dlrm_init, cfg=cfg)
        fwd = functools.partial(rs.dlrm_forward, cfg=cfg)
    elif arch == "xdeepfm":
        init = functools.partial(rs.xdeepfm_init, cfg=cfg)
        fwd = functools.partial(rs.xdeepfm_forward, cfg=cfg)
    else:
        init = functools.partial(rs.seqrec_init, cfg=cfg)
        fwd = None
    return init, fwd


def _recsys_batch(arch: str, cfg, b: int):
    if arch == "dlrm-rm2":
        shapes = {"dense": _sds((b, cfg.n_dense), jnp.float32),
                  "sparse": _sds((b, cfg.n_sparse, cfg.multi_hot), jnp.int32),
                  "label": _sds((b,), jnp.float32)}
    elif arch == "xdeepfm":
        shapes = {"sparse": _sds((b, cfg.n_sparse, 1), jnp.int32),
                  "label": _sds((b,), jnp.float32)}
    else:
        shapes = {"items": _sds((b, cfg.max_len), jnp.int32),
                  "pos": _sds((b, cfg.max_len), jnp.int32),
                  "neg": _sds((b, cfg.max_len), jnp.int32)}
    return shapes


def _recsys_flops(arch: str, cfg, b: int) -> float:
    if arch == "dlrm-rm2":
        mlp = sum(a * o for a, o in zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:]))
        top_in = cfg.embed_dim + 27 * 26 // 2
        tops = [top_in] + list(cfg.top_mlp_hidden)
        mlp += sum(a * o for a, o in zip(tops[:-1], tops[1:]))
        inter = 27 * 27 * cfg.embed_dim
        return 2.0 * b * (mlp + inter)
    if arch == "xdeepfm":
        m, dd = cfg.n_sparse, cfg.embed_dim
        cin = 0
        h_prev = m
        for h in cfg.cin_layers:
            cin += h_prev * m * dd + h * h_prev * m * dd
            h_prev = h
        dnn_sizes = [m * dd] + list(cfg.mlp) + [1]
        dnn = sum(a * o for a, o in zip(dnn_sizes[:-1], dnn_sizes[1:]))
        return 2.0 * b * (cin + dnn)
    d, s = cfg.embed_dim, cfg.max_len
    per_tok = 4 * d * d + 2 * cfg.d_ff_mult * d * d + 2 * s * d
    return 2.0 * b * s * cfg.n_blocks * per_tok


def build_recsys_cell(arch: str, shape: str, mesh: Mesh) -> BuiltCell:
    mod = registry.get(arch)
    cfg = mod.full_config()
    d = RECSYS_SHAPE_DEFS[shape]
    dp = tuple(data_axes(mesh))
    every = dp + ("model",)
    rules = shd.lm_activation_rules(mesh, _DummyAttn(), "train")
    rules["act_bfd"] = P(dp, None, None)
    b = d["batch"]
    init, fwd = _recsys_model(arch, cfg)
    params_shapes = jax.eval_shape(lambda: init(jax.random.key(0)))
    p_specs = shd.param_specs(params_shapes, mesh)

    if d["kind"] == "train":
        opt = make_optimizer(mod.OPTIMIZER)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        state_specs = {"params": p_specs,
                       "opt": opt.state_spec(params_shapes, p_specs)}
        if arch in ("sasrec", "bert4rec"):
            def loss_fn(params, batch):
                return rs.seqrec_bce_loss(params, batch["items"],
                                          batch["pos"], batch["neg"], cfg), {}
        else:
            def loss_fn(params, batch):
                args = ([batch["dense"], batch["sparse"]]
                        if "dense" in batch else [batch["sparse"]])
                logits = fwd(params, *args)
                l = batch["label"]
                loss = jnp.mean(jnp.maximum(logits, 0) - logits * l
                                + jnp.log1p(jnp.exp(-jnp.abs(logits))))
                return loss, {}
        step = make_train_step(loss_fn, opt)
        batch_shapes = _recsys_batch(arch, cfg, b)
        batch_specs = jax.tree.map(
            lambda s: P(*((dp,) + (None,) * (len(s.shape) - 1))), batch_shapes)
        return BuiltCell(
            arch, shape, "train", step,
            ({"params": params_shapes, "opt": opt_shapes}, batch_shapes),
            (_named(mesh, state_specs), _named(mesh, batch_specs)),
            (_named(mesh, state_specs), None), rules,
            {"model_flops": 3 * _recsys_flops(arch, cfg, b)})

    if d["kind"] == "serve":
        if arch in ("sasrec", "bert4rec"):
            scorer = make_batched_scorer(mesh, k=100,
                                         table_axes=("model",), batch_axes=dp)
            def serve(params, items):
                repr_ = rs.seqrec_session_repr(params, items, cfg)
                return scorer(repr_, params["item_emb"])
            batch_shapes = (_sds((b, cfg.max_len), jnp.int32),)
            batch_specs = (P(dp, None),)
            # item_emb is param-sharded over every axis; scorer expects
            # "model"-sharded -> spec mismatch is resolved by SPMD reshard.
        else:
            bs = _recsys_batch(arch, cfg, b)
            bs.pop("label")
            batch_shapes = tuple(bs.values())
            batch_specs = tuple(
                P(*((dp,) + (None,) * (len(s.shape) - 1)))
                for s in batch_shapes)

            def serve(params, *args):
                return fwd(params, *args)
        return BuiltCell(
            arch, shape, "serve", serve,
            (params_shapes,) + tuple(batch_shapes),
            (_named(mesh, p_specs),) + tuple(
                NamedSharding(mesh, s) for s in batch_specs),
            None, rules,
            {"model_flops": _recsys_flops(arch, cfg, b)})

    # retrieval_cand: one query vs 1e6 candidates == the paper's index scan.
    # The full (shard-divisible, 2^20-row) item table is scored with rows
    # past n_candidates masked — slicing an unevenly-sharded table forces a
    # full reshard-gather (measured: the whole 6.7 GB table replicated).
    n_cand = d["n_candidates"]
    scorer = make_batched_scorer(mesh, k=1000, table_axes=every,
                                 batch_axes=())
    if arch in ("sasrec", "bert4rec"):
        def retrieve(params, items):
            repr_ = rs.seqrec_session_repr(params, items, cfg)
            return scorer(repr_, params["item_emb"], n_valid=n_cand)
        batch_shapes = (_sds((b, cfg.max_len), jnp.int32),)
    elif arch == "dlrm-rm2":
        def retrieve(params, dense, sparse):
            u = rs.dlrm_user_tower(params, dense, sparse, cfg)
            return scorer(u, params["tables"][0], n_valid=n_cand)
        batch_shapes = (_sds((b, cfg.n_dense), jnp.float32),
                        _sds((b, cfg.n_sparse, cfg.multi_hot), jnp.int32))
    else:
        def retrieve(params, sparse):
            u = rs.xdeepfm_user_tower(params, sparse, cfg)
            return scorer(u, params["tables"][0], n_valid=n_cand)
        batch_shapes = (_sds((b, cfg.n_sparse, 1), jnp.int32),)
    batch_specs = tuple(P() for _ in batch_shapes)
    return BuiltCell(
        arch, shape, "retrieval", retrieve,
        (params_shapes,) + batch_shapes,
        (_named(mesh, p_specs),) + tuple(
            NamedSharding(mesh, s) for s in batch_specs),
        None, rules,
        {"model_flops": 2.0 * n_cand * cfg.embed_dim
         + _recsys_flops(arch, cfg, b)})


class _DummyAttn:
    n_heads = 1
    n_kv_heads = 1
    attention = "gqa"


# ----------------------------------------------------------------- entry

def build_cell(arch: str, shape: str, mesh: Mesh) -> BuiltCell:
    fam = registry.get(arch).FAMILY
    builder = {"lm": build_lm_cell, "gnn": build_gnn_cell,
               "recsys": build_recsys_cell}[fam]
    return builder(arch, shape, mesh)
