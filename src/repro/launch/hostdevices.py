"""Force a multi-device CPU topology BEFORE jax's first import.

jax locks the device count at first init, so every entry point that wants
virtual host devices (dry-run, benchmarks, tests) must set the flag before
importing jax anywhere in the process.  This module is deliberately
jax-free so it can be imported first.
"""

from __future__ import annotations

import os
import re


def ensure_host_devices(n: int = 8, *, override: bool = False) -> None:
    """Set --xla_force_host_platform_device_count=n in XLA_FLAGS.

    By default an already-present device-count flag wins (respect an
    explicit operator choice).  ``override=True`` replaces it — for entry
    points whose meshes only exist at a fixed topology (the 512-device
    dry-run would otherwise fail, or silently record evidence for the
    wrong mesh)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        if not override:
            return
        flags = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                       flags).strip()
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()
