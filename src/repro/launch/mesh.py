"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(n_devices: int | None = None, model_parallel: int = 16):
    """Best mesh for an arbitrary (possibly degraded) device count — the
    elastic-restart path: keep TP fixed at what fits a model replica, put
    everything else on data."""
    n = n_devices or len(jax.devices())
    while n % model_parallel and model_parallel > 1:
        model_parallel //= 2
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_host_mesh():
    """1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
