"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

``cost_analysis()`` FLOPs/bytes are for the *partitioned per-device* module,
so terms are computed directly against single-chip peaks.  Collective bytes
are not in cost_analysis: we parse the optimized (post-SPMD) HLO and sum
the output-shape bytes of every collective op (for all-gather this counts
the gathered result, a standard upper bound on the per-device ring traffic;
for reduce-scatter the scattered output understates by ~(n-1)/n — noted).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

HW = {
    "flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (per device) from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        # avoid double-counting async start/done pairs: count starts and
        # plain (sync) ops; skip "-done"
        if "-done(" in line:
            continue
        out[kind] += _shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per-device HLO flops
    hbm_bytes: float          # per-device bytes accessed
    coll_bytes: float         # per-device collective bytes
    model_flops: float        # analytic useful flops (global)
    n_devices: int

    @property
    def t_compute(self) -> float:
        return self.flops / HW["flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / HW["ici_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else float("nan")

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-roofline bound spent doing useful model
        flops: (model_flops / chips / peak) / bound_time."""
        ideal = self.model_flops / self.n_devices / HW["flops_bf16"]
        return ideal / self.bound_time if self.bound_time else float("nan")

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "model_flops": self.model_flops,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, model_flops: float, n_devices: int,
            hlo_text: Optional[str] = None) -> tuple[Roofline, dict]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):          # some backends return [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    rl = Roofline(flops=flops, hbm_bytes=byts, coll_bytes=float(coll["total"]),
                  model_flops=model_flops, n_devices=n_devices)
    return rl, coll


def memory_summary(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        m = None
    if m is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, f, None)
        if v is not None:
            out[f] = int(v)
    if "argument_size_in_bytes" in out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out
