"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective evidence.

MUST be the process entry point (jax locks the device count on first
init); the ``ensure_host_devices`` call below precedes every other
import for that reason.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

import os

from repro.launch.hostdevices import ensure_host_devices
ensure_host_devices(512, override=True)   # production meshes need 512

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import registry               # noqa: E402
from repro.dist.api import sharding_rules        # noqa: E402
from repro.launch import roofline as rl          # noqa: E402
from repro.launch.cells import build_cell        # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, calibrate: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    with sharding_rules(mesh, cell.rules):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    roof, coll = rl.analyze(compiled, cell.meta.get("model_flops", 0.0), n_dev,
                            hlo_text=hlo)
    mem = rl.memory_summary(compiled)

    calib = None
    if calibrate and registry.get(arch).FAMILY == "lm":
        from repro.launch.calibrate import calibrated_costs
        calib = calibrated_costs(arch, shape, mesh)
        tot = calib["total"]
        roof = rl.Roofline(flops=tot["flops"], hbm_bytes=tot["bytes"],
                           coll_bytes=tot["coll"],
                           model_flops=cell.meta.get("model_flops", 0.0),
                           n_devices=n_dev)
    record = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "collectives": coll,
        "roofline": roof.to_dict(),
        "calibration": calib,
        "meta": cell.meta,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}@{shape}@{record['mesh']}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
            f.write(hlo)
    print(f"[dryrun] {tag}: OK  "
          f"(compile {t_compile:.1f}s, dominant={roof.dominant}, "
          f"t=({roof.t_compute:.2e},{roof.t_memory:.2e},"
          f"{roof.t_collective:.2e})s, "
          f"hbm/dev={mem.get('total_hbm_bytes', 0)/2**30:.2f}GiB)")
    print("  memory_analysis:", mem)
    print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e"
          % (roof.flops, roof.hbm_bytes))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="trip-count-corrected costs for LM cells "
                         "(extra reduced-layer compiles)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose artifact JSON already exists")
    args = ap.parse_args()

    cells = (registry.all_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch}@{shape}@{'2x16x16' if multi_pod else '16x16'}"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, tag + ".json")):
                continue
            try:
                run_cell(arch, shape, multi_pod, args.out,
                         save_hlo=args.save_hlo, calibrate=args.calibrate)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, multi_pod, repr(e)))
                print(f"[dryrun] {arch}@{shape} multi_pod={multi_pod} "
                      f"FAILED: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells compiled successfully")


if __name__ == "__main__":
    main()
