"""Trip-count-corrected cost analysis for scanned LM cells.

XLA's ``cost_analysis()`` counts each ``while`` body ONCE, so a scanned
61-layer model reports ~1 layer of flops/bytes/collectives.  Correction:
compile *calibration variants* of the same cell with the layer scan and the
attention chunk scans fully unrolled, at reduced layer counts, and
extrapolate linearly:

  total(kinds) = trunk + sum_kind L_kind * delta_kind

with per-kind deltas measured from compiles that increment one group's layer
count at a time (dense: L in {1,2}; +MoE: {(1,1),(2,1),(2,2)}).  Unrolled
calibration compiles are exact — every dot is in straight-line HLO — and the
extrapolation is exact too because layers within a kind are homogeneous.

The REAL (scanned, rematted) artifact is still what proves compile/memory;
calibration only fixes the *cost* numbers.  Remat note: with full remat the
true executed flops are ~1.33x fwd+bwd (fwd replayed); calibration variants
keep the same remat policy inside jax.checkpoint, but unrolled-without-scan
checkpoint regions may be CSE'd by XLA — we therefore report calibrated
flops as the *algorithmic* (no-recompute) cost and list the remat multiplier
separately in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.configs import registry
from repro.dist.api import sharding_rules
from repro.launch import roofline as rl
from repro.launch.cells import build_lm_cell


def _costs(arch: str, shape: str, mesh, cfg) -> dict:
    cell = build_lm_cell(arch, shape, mesh, cfg_override=cfg)
    with sharding_rules(mesh, cell.rules):
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings).lower(
            *cell.args).compile()
    cost = compiled.cost_analysis() or {}
    coll = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def _with_layers(cfg, n_dense: int, n_moe: int):
    total = n_dense + n_moe
    return dataclasses.replace(cfg, n_layers=total, n_dense_layers=n_dense,
                               attn_unroll=True, layer_unroll=True,
                               mtp=cfg.mtp)


def calibrated_costs(arch: str, shape: str, mesh) -> dict:
    """Extrapolated per-device flops/bytes/collective-bytes for the cell."""
    cfg = registry.get(arch).full_config()
    if cfg.moe is None:
        l_dense, l_moe = cfg.n_layers, 0
        c1 = _costs(arch, shape, mesh, _with_layers(cfg, 1, 0))
        c2 = _costs(arch, shape, mesh, _with_layers(cfg, 2, 0))
        delta_d = {k: c2[k] - c1[k] for k in c1}
        trunk = {k: c1[k] - delta_d[k] for k in c1}
        total = {k: trunk[k] + l_dense * delta_d[k] for k in c1}
        per_layer = {"dense": delta_d}
    else:
        l_dense = max(cfg.n_dense_layers, 0)
        l_moe = cfg.n_layers - l_dense
        # MoE capacity depends only on token count, not layer count -> the
        # per-layer deltas transfer exactly.
        c11 = _costs(arch, shape, mesh, _with_layers(cfg, 1, 1))
        c21 = _costs(arch, shape, mesh, _with_layers(cfg, 2, 1))
        c22 = _costs(arch, shape, mesh, _with_layers(cfg, 2, 2))
        delta_d = {k: c21[k] - c11[k] for k in c11}
        delta_m = {k: c22[k] - c21[k] for k in c11}
        trunk = {k: c11[k] - delta_d[k] - delta_m[k] for k in c11}
        if l_dense == 0:
            # model has no dense layers; fold the measured dense delta away
            total = {k: trunk[k] + delta_d[k] * 0 + l_moe * delta_m[k]
                     for k in c11}
        else:
            total = {k: trunk[k] + l_dense * delta_d[k] + l_moe * delta_m[k]
                     for k in c11}
        per_layer = {"dense": delta_d, "moe": delta_m}
    return {"total": total, "trunk": trunk, "per_layer": per_layer,
            "layers": {"dense": l_dense, "moe": l_moe}}
