"""Topical-locality clustering subsystem tests (repro.core.cluster):
tier-identical k-means assignment across storage dtypes, ClusterIndex
invariants + persistence, prefetch claim soundness, the prefetch wave's
launch-count / zero-copy contracts, cluster-aware L2 admission, and the
end-to-end hit-rate win the serve_bench Pareto sweep gates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_ops import insert_query_batched, probe_batched
from repro.core.cluster import (ClusterIndex, assign_clusters,
                                build_cluster_index)
from repro.core.metric_index import MetricIndex
from repro.core.shared import SharedTier
from repro.data.conversations import WorldConfig, make_world
from repro.kernels import jaxpr_util
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.session import BatchedEngine

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # clustering rides the kNN scan contract


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _topical_world(**overrides):
    """The prefetch win regime: few dense topics in a tiny subspace, small
    query noise, misses driven by subtopic jumps — and ``norm_jitter=0`` so
    the Eq. 1 appended coordinate doesn't inflate query-centroid distances
    (the triangle-inequality widening needs d_w > r_a + delta)."""
    cfg = dict(n_topics=4, docs_per_topic=300, n_background=600, dim=48,
               subspace_dim=4, turns=6, n_conversations=6, doc_sigma=0.8,
               query_sigma=0.05, drift_sigma=0.08, subtopic_prob=0.4,
               subtopic_sigma=0.45, norm_jitter=0.0, seed=11)
    cfg.update(overrides)
    return make_world(WorldConfig(**cfg))


# -------------------------------------------------- assignment equivalence
@pytest.mark.parametrize("dtype", ["fp32", "bf16", "int8"])
def test_assignment_ref_interpret_identical(dtype):
    """The k-means assignment step is the scan_topk contract at k=1: ref
    and interpret tiers pick the SAME centroid for every document of the
    dequantized corpus, at every storage dtype."""
    rng = np.random.default_rng(3)
    docs = _unit(rng, (257, 32))          # odd count: exercises chunk tails
    index = MetricIndex(jnp.asarray(docs), dtype=dtype)
    # the clustering space is the Eq. 1 TRANSFORMED corpus view (dim + 1);
    # seed centroids from corpus rows so dimensions line up by construction
    corpus = np.asarray(index.dequantized())[:index.n_docs]
    cents = corpus[rng.choice(index.n_docs, size=7, replace=False)]
    a_ref, s_ref = assign_clusters(corpus, cents, backend="ref",
                                   query_chunk=64)
    a_int, s_int = assign_clusters(corpus, cents, backend="interpret",
                                   query_chunk=64)
    np.testing.assert_array_equal(a_ref, a_int)
    np.testing.assert_allclose(s_ref, s_int, atol=1e-5)
    assert a_ref.dtype == np.int32 and a_ref.shape == (257,)
    # winning score really is the max inner product against the centroids
    np.testing.assert_allclose(s_ref, (corpus @ cents.T).max(axis=1),
                               atol=1e-5)


def test_build_recovers_planted_topics():
    """On a world of well-separated planted topics, over-clustering at
    K = 2 x n_topics yields topic-PURE clusters (splitting a topic is
    fine, merging two is not) and the ClusterIndex invariants hold:
    members partition the corpus, neighbor distances ascend, centrality
    ordering puts closer members first."""
    world = _topical_world(n_background=0, docs_per_topic=200)
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))
    ci = build_cluster_index(index, 8, iters=10, seed=0, max_width=64,
                             backend="ref")
    assert ci.n_clusters == 8 and ci.n_docs == index.n_docs
    # topic purity: doc i belongs to topic i // docs_per_topic
    topic = np.arange(ci.n_docs) // 200
    for c in range(8):
        mem = ci.members(c)
        if len(mem):
            assert np.unique(topic[mem]).size == 1
    # members partition the corpus exactly once
    assert ci.sizes.sum() == ci.n_docs
    np.testing.assert_array_equal(np.sort(ci.member_ids),
                                  np.arange(ci.n_docs))
    # member lists are ordered most-central first
    docs = np.asarray(index.dequantized())[:index.n_docs]
    for c in range(8):
        scores = docs[ci.members(c)] @ ci.centroids[c]
        assert (np.diff(scores) <= 1e-5).all()
    # neighbor tables ascend in distance
    assert (np.diff(ci.near_d, axis=1) >= -1e-5).all()
    # cluster_of maps corpus ids to assignments, sentinels to -1
    np.testing.assert_array_equal(ci.cluster_of(np.arange(ci.n_docs)),
                                  ci.assign)
    np.testing.assert_array_equal(
        ci.cluster_of(np.array([-1, ci.n_docs, ci.n_docs + 7])),
        np.array([-1, -1, -1]))


def test_prefetch_claim_bound_is_sound():
    """The triangle-inequality widening: after prefetching width-w
    neighbors of the query's centroid, EVERY corpus document within the
    returned claim bound of the query is in (answer + extras)."""
    world = _topical_world()
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))
    ci = build_cluster_index(index, 8, iters=10, seed=0, max_width=400,
                             backend="ref")
    docs = np.asarray(index.dequantized())[:index.n_docs]
    rng = np.random.default_rng(5)
    checked = 0
    for conv in world.conversations:
        psi = np.asarray(index.transform_queries(
            jnp.asarray(conv.queries[:1], jnp.float32)))[0]
        answer = rng.choice(index.n_docs, size=20, replace=False)
        extra, bound = ci.prefetch(psi, answer, 300)
        assert extra.size <= 300
        assert not np.isin(extra, answer).any()
        if bound <= 0.0:
            continue
        cached = set(answer.tolist()) | set(extra.tolist())
        dist = np.sqrt(np.maximum(2.0 - 2.0 * (docs @ psi), 0.0))
        inside = np.nonzero(dist <= bound)[0]
        assert all(int(d) in cached for d in inside)
        checked += 1
    assert checked > 0                 # the regime actually widened claims
    # width 0 and a too-large width degrade gracefully
    empty, b0 = ci.prefetch(psi, answer, 0)
    assert empty.size == 0 and b0 == 0.0
    wide, _ = ci.prefetch(psi, answer, 10 ** 6)
    assert wide.size <= ci.max_width


def test_save_load_and_metric_index_memoization(tmp_path):
    """ClusterIndex round-trips through .npz; MetricIndex.cluster memoizes
    per parameters and reloads from ``path`` instead of rebuilding."""
    rng = np.random.default_rng(9)
    index = MetricIndex(jnp.asarray(_unit(rng, (120, 16))))
    ci = index.cluster(5, iters=4, seed=1, max_width=12, backend="ref")
    assert index.cluster(5, iters=4, seed=1, max_width=12,
                         backend="ref") is ci      # memoized
    path = tmp_path / "clusters.npz"
    ci.save(path)
    back = ClusterIndex.load(path)
    np.testing.assert_array_equal(back.assign, ci.assign)
    np.testing.assert_allclose(back.centroids, ci.centroids)
    np.testing.assert_array_equal(back.near_ids, ci.near_ids)
    assert back.n_iters == ci.n_iters
    assert back.memory_bytes() == ci.memory_bytes()
    # a fresh MetricIndex loads the artifact rather than re-clustering
    other = MetricIndex(jnp.asarray(_unit(rng, (120, 16))))
    loaded = other.cluster(5, iters=4, seed=1, max_width=12, backend="ref",
                           path=path)
    np.testing.assert_array_equal(loaded.assign, ci.assign)


# ------------------------------------------------ cluster-aware admission
def _toy_cluster(assign):
    """Hand-built ClusterIndex over ``assign`` (neighbor tables unused by
    admission)."""
    assign = np.asarray(assign, np.int32)
    k = int(assign.max()) + 1
    order = np.argsort(assign, kind="stable")
    offsets = np.zeros(k + 1, np.int64)
    np.cumsum(np.bincount(assign, minlength=k), out=offsets[1:])
    dim = 8
    cents = np.eye(k, dim, dtype=np.float32)
    return ClusterIndex(cents, assign, offsets, order.astype(np.int64),
                        np.full((k, 2), -1, np.int64),
                        np.zeros((k, 2), np.float32))


def test_cluster_admission_promotes_topical_siblings():
    """Two sessions retrieving DIFFERENT documents of the same cluster
    promote (the cluster is popular); per-doc admission on the same offers
    does not (no single document saw two sessions)."""
    ci = _toy_cluster([0, 0, 0, 0, 1, 1, 1, 1])
    rng = np.random.default_rng(21)
    emb = _unit(rng, (8, 16))

    def offers(tier):
        tier.tick()
        a = tier.offer(("a", 1), _unit(rng, (16,)), 0.5,
                       emb[[0, 1]], np.array([0, 1]))
        b = tier.offer(("b", 1), _unit(rng, (16,)), 0.5,
                       emb[[2, 3]], np.array([2, 3]))
        return a, b

    clustered = SharedTier(dim=16, n_shards=2, capacity=64, max_queries=5,
                           backend="interpret", cluster=ci)
    a, b = offers(clustered)
    assert not a and b                  # second distinct session on cluster 0
    assert clustered.flush_admissions() == 1
    assert clustered.contains(np.array([2, 3])).all()

    per_doc = SharedTier(dim=16, n_shards=2, capacity=64, max_queries=5,
                         backend="interpret")
    assert offers(per_doc) == (False, False)     # docs disjoint: no promotion
    assert per_doc.flush_admissions() == 0


def test_cluster_admission_same_session_never_promotes():
    """Repeat offers from ONE session leave the cluster unpromoted, and
    out-of-corpus ids fall back to per-doc keys without colliding."""
    ci = _toy_cluster([0, 0, 0, 0])
    rng = np.random.default_rng(22)
    emb = _unit(rng, (2, 16))
    tier = SharedTier(dim=16, n_shards=2, capacity=64, max_queries=5,
                      backend="interpret", cluster=ci)
    tier.tick()
    for ids in ([0, 1], [2, 3], [0, 3]):
        assert not tier.offer(("a", 1), _unit(rng, (16,)), 0.5,
                              emb, np.array(ids))
    assert tier.flush_admissions() == 0
    # ids beyond the clustered corpus key per-doc (negative fallback keys)
    assert not tier.offer(("a", 1), _unit(rng, (16,)), 0.5,
                          emb, np.array([100, 101]))
    assert tier.offer(("b", 1), _unit(rng, (16,)), 0.5,
                      emb, np.array([100, 101]))


# ------------------------------------------- serving integration + launches
def _mini_engine(rng, *, width, shared=False, backend="interpret"):
    """Tiny corpus + cluster + engine for the wave-contract tests; serving
    runs in the Eq. 1 TRANSFORMED space (dim + 1), matching the cluster."""
    n, d = 300, 48
    index = MetricIndex(jnp.asarray(_unit(rng, (n, d))))
    docs = np.asarray(index.dequantized())[:n]
    dim = docs.shape[1]
    ci = build_cluster_index(index, 6, iters=4, seed=0, max_width=64,
                             backend="ref")
    # a device shard on the SAME dispatch tier, so the wave's miss-search
    # launch is counted alongside the cache launches
    from repro.dist.retrieval import DeviceShard
    shard = DeviceShard(jnp.asarray(docs), jnp.arange(n, dtype=jnp.int32),
                        backend=backend)
    router = ShardedRouter([shard], deadline_s=120.0)
    # admission_sessions above the wave size: cluster-aware admission
    # would otherwise promote on the FIRST wave (three sessions can share
    # one topical cluster), adding the flush launch to the counted wave
    tier = SharedTier(dim=dim, n_shards=2, capacity=128, max_queries=8,
                      admission_sessions=4, backend=backend,
                      cluster=ci) if shared else None
    eng = BatchedEngine(router, docs, dim=dim, n_sessions=4, k=5, k_c=17,
                        capacity=256, backend=backend, shared=tier,
                        cluster=ci, prefetch_width=width)
    return eng, index


def test_prefetch_width_validated_against_tables():
    rng = np.random.default_rng(30)
    with pytest.raises(ValueError, match="max_width"):
        _mini_engine(rng, width=65)


@pytest.mark.slow
def test_prefetch_miss_wave_is_three_launches(monkeypatch):
    """Prefetch folding preserves the L1-only wave contract: a miss wave
    with cluster neighbors appended is STILL exactly three Pallas launches
    (probe -> miss-search -> fused insert+query) — the expansion rides the
    same fused insert, never a launch of its own."""
    import jax.experimental.pallas as plmod

    rng = np.random.default_rng(31)

    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)
    eng, index = _mini_engine(rng, width=32, shared=False)
    qs = np.asarray(index.transform_queries(
        jnp.asarray(_unit(rng, (3, 48)))))
    jax.clear_caches()
    calls["n"] = 0
    turns = eng.answer_batch([0, 1, 2], [jnp.asarray(q) for q in qs])
    assert all(t.tier == "backend" for t in turns)
    assert eng.prefetch_issued > 0
    assert calls["n"] == 3, f"prefetch miss wave traced {calls['n']} launches"


@pytest.mark.slow
def test_prefetch_tiered_miss_wave_is_four_launches(monkeypatch):
    """With the shared tier attached the prefetch-expanded full-miss wave
    keeps the tiered contract: four launches (L1 probe -> L2 probe ->
    miss-search -> fused insert+query)."""
    import jax.experimental.pallas as plmod

    rng = np.random.default_rng(32)

    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)
    eng, index = _mini_engine(rng, width=32, shared=True)
    qs = np.asarray(index.transform_queries(
        jnp.asarray(_unit(rng, (3, 48)))))
    jax.clear_caches()
    calls["n"] = 0
    turns = eng.answer_batch([0, 1, 2], [jnp.asarray(q) for q in qs])
    assert all(t.tier == "backend" for t in turns)
    assert calls["n"] == 4, f"tiered prefetch wave traced {calls['n']} launches"


def test_widened_insert_trace_is_zero_copy():
    """The (k_c + prefetch_width)-column insert traces with ZERO pad /
    slice / copy equations at the stacked payload size and one Pallas
    launch — widening the answer does not reintroduce payload copies."""
    from repro.core.cache import CacheConfig, init_batched_cache

    k_c, width, dim, s = 17, 32, 48, 3
    cfg = CacheConfig(capacity=256, dim=dim)
    state = init_batched_cache(cfg, s)
    psi = jnp.zeros((s, dim), jnp.float32)
    ids = jnp.zeros((s, k_c + width), jnp.int32)
    emb = jnp.zeros((s, k_c + width, dim), jnp.float32)
    radius = jnp.zeros((s,), jnp.float32)
    payload = s * cfg.phys_capacity * cfg.phys_dim
    jx = jax.make_jaxpr(
        lambda st, p, r, e, i: insert_query_batched(
            st, cfg, p, r, e, i, k=5, backend="interpret"))(
        state, psi, radius, emb, ids)
    assert jaxpr_util.payload_copy_eqns(jx, payload) == []
    assert jaxpr_util.pallas_call_count(jx) == 1
    # the widened probe shape stays single-launch zero-copy too
    jx = jax.make_jaxpr(
        lambda st, p: probe_batched(st, p, cfg.epsilon, backend="interpret",
                                    max_queries=cfg.max_queries))(state, psi)
    assert jaxpr_util.payload_copy_eqns(jx, payload) == []
    assert jaxpr_util.pallas_call_count(jx) == 1


@pytest.mark.slow
def test_prefetch_lifts_hit_rate_in_topical_regime():
    """End-to-end acceptance: replaying the topical world with prefetch
    beats the same engine without it — strictly higher combined hit rate,
    nonzero warm hits attributed on turns, more insert traffic (the Pareto
    trade the bench sweep charts)."""
    world = _topical_world()
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))
    ci = index.cluster(8, iters=10, seed=0, max_width=400, backend="ref")
    n_sessions = len(world.conversations)
    streams = [np.asarray(index.transform_queries(
        jnp.asarray(c.queries, jnp.float32))) for c in world.conversations]
    docs = np.asarray(index.dequantized())
    ids = np.arange(index.n_docs)

    def run(width):
        def shard(queries, k):
            scores = queries @ docs[:index.n_docs].T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               ids[top])
        router = ShardedRouter([shard], deadline_s=30.0)
        eng = BatchedEngine(router, docs, dim=index.dim,
                            n_sessions=n_sessions, k=5, k_c=20,
                            capacity=4096, backend="ref",
                            cluster=ci if width else None,
                            prefetch_width=width)
        sids = list(range(n_sessions))
        for s in sids:
            eng.start_session(s)
        pref_turns = 0
        for t in range(streams[0].shape[0]):
            for turn in eng.answer_batch(sids,
                                         [streams[s][t] for s in sids]):
                pref_turns += turn.prefetch_hits > 0
        return eng, pref_turns

    base, _ = run(0)
    pref, pref_turns = run(400)
    assert base.prefetch_issued == 0 and base.prefetch_warm_hits == 0
    assert pref.prefetch_issued > 0 and pref.prefetch_warm_hits > 0
    assert pref_turns > 0                       # per-turn attribution flows
    assert pref.hit_rate() > base.hit_rate()    # the gated headline
    # the price: prefetch pushes more docs through the insert launches
    assert pref.insert_traffic_docs > base.insert_traffic_docs
    stats = pref.prefetch_stats()
    assert stats["width"] == 400
    assert stats["warm_hits"] == pref.prefetch_warm_hits
