"""Quantized corpus storage (repro.core.quant): round-trip properties and
the storage-dtype policy plumbing through cache / index / engines."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.cache import CacheConfig, MetricCache
from repro.core.metric_index import MetricIndex

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def test_int8_roundtrip_preserves_unit_norm_exactly():
    """The int8 scale is renormalized so dequantized rows keep the original
    norm to f32 rounding — the invariant the Eq. 1 metric machinery needs."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(_unit(rng, (257, 65)))
    qc = quant.quantize(x, "int8")
    assert qc.data.dtype == jnp.int8 and qc.scale.shape == (257,)
    norms = np.linalg.norm(np.asarray(quant.dequantize(qc)), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    # cosine error of the payload direction stays small
    cos = np.sum(np.asarray(quant.dequantize(qc)) * np.asarray(x), axis=1)
    assert cos.min() > 0.9999


def test_bf16_roundtrip_and_fp32_identity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(_unit(rng, (64, 33)))
    qb = quant.quantize(x, "bf16")
    assert qb.data.dtype == jnp.bfloat16 and qb.scale is None
    np.testing.assert_allclose(np.asarray(quant.dequantize(qb)),
                               np.asarray(x), atol=4e-3)
    qf = quant.quantize(x, "fp32")
    assert qf.scale is None
    np.testing.assert_array_equal(np.asarray(qf.data), np.asarray(x))


def test_zero_rows_quantize_to_neutral_sentinels():
    """All-zero (sentinel-pad) rows must round-trip to zero with scale 1 —
    no NaN/inf from the norm renormalization."""
    x = jnp.zeros((4, 16), jnp.float32)
    qc = quant.quantize(x, "int8")
    np.testing.assert_array_equal(np.asarray(qc.data), 0)
    np.testing.assert_array_equal(np.asarray(qc.scale), 1.0)
    assert np.isfinite(np.asarray(quant.dequantize(qc))).all()


def test_dtype_policy_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_CORPUS_DTYPE", raising=False)
    assert quant.default_dtype() == "fp32"
    assert quant.resolve_dtype(None) == "fp32"
    monkeypatch.setenv("REPRO_CORPUS_DTYPE", "int8")
    assert quant.default_dtype() == "int8"
    assert quant.resolve_dtype(None) == "int8"
    assert quant.resolve_dtype("bf16") == "bf16"  # explicit beats env
    monkeypatch.setenv("REPRO_CORPUS_DTYPE", "fp64")
    with pytest.raises(ValueError):
        quant.default_dtype()
    with pytest.raises(ValueError):
        quant.resolve_dtype("float32")


def test_metric_index_storage_follows_dtype():
    rng = np.random.default_rng(2)
    raw = jnp.asarray(rng.standard_normal((100, 24)).astype(np.float32))
    idx8 = MetricIndex(raw, dtype="int8", use_kernel=False)
    assert idx8.doc_emb.dtype == jnp.int8 and idx8.doc_scale is not None
    idx32 = MetricIndex(raw, dtype="fp32", use_kernel=False)
    assert idx32.doc_emb.dtype == jnp.float32 and idx32.doc_scale is None
    # dequantized() hands back f32 for host-side lookups at any dtype
    assert idx8.dequantized().dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(idx8.dequantized()),
                               np.asarray(idx32.dequantized()), atol=2e-2)


@pytest.mark.parametrize("dt,factor", [("bf16", 2), ("int8", 4)])
def test_cache_memory_shrinks_with_store_dtype(dt, factor):
    base = MetricCache(CacheConfig(capacity=1024, dim=256, max_queries=16))
    small = MetricCache(CacheConfig(capacity=1024, dim=256, max_queries=16,
                                    store_dtype=dt))
    # embeddings dominate at this shape; allow slack for ids/stamps/scales
    assert base.memory_bytes() > 0.8 * factor * small.memory_bytes()


def test_fp32_store_dtype_is_bit_identical_to_seed_layout():
    """store_dtype='fp32' must be a true no-op: same probe/query results
    bit for bit (scales are exactly 1.0)."""
    rng = np.random.default_rng(3)
    cfgs = [CacheConfig(capacity=32, dim=17, max_queries=4, store_dtype="fp32")]
    caches = [MetricCache(c) for c in cfgs]
    cache = caches[0]
    for _ in range(5):
        psi = jnp.asarray(_unit(rng, (17,)))
        emb = jnp.asarray(_unit(rng, (3, 17)))
        ids = jnp.asarray(rng.integers(0, 50, 3), jnp.int32)
        cache.insert(psi, float(rng.uniform(0.3, 1.0)), emb, ids)
    st = cache.state
    np.testing.assert_array_equal(np.asarray(st.doc_scale), 1.0)
    np.testing.assert_array_equal(np.asarray(st.q_scale), 1.0)
    assert st.doc_emb.dtype == jnp.float32


def test_engine_dtype_param_reaches_cache_storage():
    from repro.serve.session import BatchedEngine

    class _NullRouter:
        def search(self, q, k):
            raise TimeoutError("not used")

    doc = np.zeros((10, 8), np.float32)
    eng = BatchedEngine(_NullRouter(), doc, dim=8, n_sessions=2, k_c=4,
                        dtype="int8")
    assert eng.cache.state.doc_emb.dtype == jnp.int8
    if "REPRO_CORPUS_DTYPE" not in os.environ:
        eng_default = BatchedEngine(_NullRouter(), doc, dim=8, n_sessions=2,
                                    k_c=4)
        assert eng_default.cache.state.doc_emb.dtype == jnp.float32


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_conversational_searcher_over_quantized_index(dt):
    """Regression: Algorithm 1 used to insert the raw quantized index
    payload (int8 integers in [-127, 127]) into the cache instead of the
    dequantized f32 view, so cached rankings were garbage.  A miss turn's
    top-k answered FROM THE CACHE must equal the index's own top-k."""
    from repro.core.conversation import ConversationalSearcher
    rng = np.random.default_rng(5)
    raw = jnp.asarray(rng.standard_normal((400, 32)).astype(np.float32))
    # pin the dequantize-first rule: the cache always scores that way, so
    # under REPRO_INT8_DOT=1 an int8-MXU index may legally swap near-ties
    # vs the cache — this test is about cache payload corruption, not the
    # scoring-rule drift (gated elsewhere)
    idx = MetricIndex(raw, dtype=dt, use_kernel=False, int8_dot=False)
    searcher = ConversationalSearcher(idx, k=10, k_c=50, epsilon=0.04)
    assert searcher.cache.cfg.store_dtype == dt
    searcher.start_conversation()
    psi = idx.transform_queries(
        jnp.asarray(rng.standard_normal(32).astype(np.float32)))
    rec = searcher.answer(psi)
    assert not rec.hit                       # compulsory first miss
    direct = idx.search(psi[None], 10)
    np.testing.assert_array_equal(np.asarray(rec.ids).reshape(-1),
                                  np.asarray(direct.ids).reshape(-1))
