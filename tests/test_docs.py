"""Documentation gates (tier-1): the knob reference must cover every
live constructor parameter and ``REPRO_*`` environment variable, every
relative markdown link must resolve, and every ``src/repro`` module must
open with a docstring.  These run in the CI docs job alongside the ruff
pydocstyle subset."""

import ast
import inspect
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]


@pytest.fixture(scope="module")
def knobs_text() -> str:
    return (ROOT / "docs" / "knobs.md").read_text()


def _ctor_knobs(obj) -> list:
    """Parameter names of a callable/constructor, minus self/varargs."""
    fn = obj.__init__ if inspect.isclass(obj) else obj
    return [name for name, p in inspect.signature(fn).parameters.items()
            if name != "self"
            and p.kind not in (p.VAR_POSITIONAL, p.VAR_KEYWORD)]


def _documented(knobs_text: str, name: str) -> bool:
    # a knob counts as documented when it appears as inline code anywhere
    # in docs/knobs.md (table cell or prose)
    return f"`{name}`" in knobs_text or f"`{name} " in knobs_text


def test_knob_reference_covers_every_constructor(knobs_text):
    """Introspect the live knob surfaces; FAIL when docs/knobs.md misses
    one — adding a parameter without documenting it breaks tier-1."""
    from repro.core.cache import CacheConfig
    from repro.core.cluster import build_cluster_index
    from repro.core.metric_index import MetricIndex
    from repro.core.shared import SharedTier
    from repro.serve.faults import FaultPlan, FaultSpec, chaos_plan
    from repro.serve.router import CircuitBreaker, ShardedRouter
    from repro.serve.scheduler import ContinuousScheduler
    from repro.serve.session import BatchedEngine, SessionManager

    surfaces = {
        "CacheConfig": list(CacheConfig._fields),
        "MetricIndex": _ctor_knobs(MetricIndex),
        "MetricIndex.cluster": _ctor_knobs(MetricIndex.cluster),
        "build_cluster_index": _ctor_knobs(build_cluster_index),
        "SharedTier": _ctor_knobs(SharedTier),
        "BatchedEngine": _ctor_knobs(BatchedEngine),
        "SessionManager": _ctor_knobs(SessionManager),
        "ContinuousScheduler": _ctor_knobs(ContinuousScheduler),
        "ShardedRouter": _ctor_knobs(ShardedRouter),
        "CircuitBreaker": _ctor_knobs(CircuitBreaker),
        "FaultSpec": list(FaultSpec.__dataclass_fields__),
        "FaultPlan": _ctor_knobs(FaultPlan),
        "chaos_plan": _ctor_knobs(chaos_plan),
    }
    missing = [f"{owner}.{knob}"
               for owner, knobs in surfaces.items()
               for knob in knobs
               if not _documented(knobs_text, knob)]
    assert not missing, (
        f"knobs missing from docs/knobs.md: {missing} — document them "
        "(one table row each) to keep the reference complete")
    # the surfaces themselves must be named too
    for owner in surfaces:
        assert owner.split(".")[0] in knobs_text, (
            f"docs/knobs.md never mentions {owner}")


def test_knob_reference_covers_every_env_var(knobs_text):
    """Every REPRO_* environment variable read anywhere in src/repro must
    have a row in the knob reference."""
    seen = set()
    for py in (ROOT / "src" / "repro").rglob("*.py"):
        seen.update(re.findall(r"REPRO_[A-Z0-9_]+", py.read_text()))
    assert seen, "expected REPRO_* policy switches in src/repro"
    missing = sorted(v for v in seen if f"`{v}`" not in knobs_text)
    assert not missing, f"env vars missing from docs/knobs.md: {missing}"


def test_markdown_links_resolve():
    """Relative links in README.md and docs/*.md must point at files that
    exist (anchors are stripped; external URLs are skipped)."""
    link_re = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
    broken = []
    for md in DOCS:
        text = md.read_text()
        # fenced code blocks may contain ](...)-looking shell snippets
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in link_re.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                broken.append(f"{md.relative_to(ROOT)} -> {target}")
    assert not broken, f"dead links: {broken}"


def test_every_module_has_a_docstring():
    """The pydocstyle-subset gate, locally: every module under src/repro
    opens with a docstring (the CI docs job enforces the same via ruff
    D100/D300/D419)."""
    missing = []
    for py in sorted((ROOT / "src" / "repro").rglob("*.py")):
        tree = ast.parse(py.read_text())
        if not ast.get_docstring(tree):
            missing.append(str(py.relative_to(ROOT)))
    assert not missing, f"modules without a docstring: {missing}"
