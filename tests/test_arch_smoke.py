"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import egnn as egnn_mod
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train.optimizer import adamw, adafactor
from repro.train.step import make_lm_train_step, make_train_step

jax.config.update("jax_platform_name", "cpu")

LM_ARCHS = ["deepseek-v3-671b", "llama4-scout-17b-16e", "chatglm3-6b",
            "mistral-large-123b", "gemma2-9b", "star-encoder"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train(arch):
    mod = registry.get(arch)
    cfg = mod.smoke_config()
    params = tf.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits, aux, hidden, _ = tf.forward(params, tokens, cfg, remat="none")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # one train step reduces nothing but must run and stay finite
    opt = adamw(lr=1e-3) if mod.OPTIMIZER == "adamw" else adafactor(lr=1e-2)
    step = make_lm_train_step(cfg, opt, remat="full")
    state = {"params": params, "opt": opt.init(params)}
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["gemma2-9b", "deepseek-v3-671b",
                                  "chatglm3-6b"])
def test_lm_smoke_decode(arch):
    cfg = registry.get(arch).smoke_config()
    params = tf.init_params(jax.random.key(0), cfg)
    caches = tf.init_kv_caches(cfg, 2, 24)
    tok = jnp.asarray([1, 2], jnp.int32)
    for t in range(3):
        logits, caches = tf.decode_step(params, tok, caches,
                                        jnp.asarray(t + 1), cfg)
        tok = logits.argmax(-1).astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_lm_train_loss_decreases():
    """A few steps on learnable (markov) data must reduce CE."""
    from repro.data.lm import LMBatchSpec, TokenStream
    cfg = registry.get("star-encoder").smoke_config()
    params = tf.init_params(jax.random.key(0), cfg)
    opt = adamw(lr=3e-3, warmup=1)
    step = jax.jit(make_lm_train_step(cfg, opt, remat="none"))
    stream = TokenStream(LMBatchSpec(global_batch=8, seq_len=32,
                                     vocab_size=cfg.vocab_size))
    state = {"params": params, "opt": opt.init(params)}
    losses = []
    for i in range(30):
        state, m = step(state, stream.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]


def test_egnn_smoke_and_equivariance():
    from repro.data.graph import batched_molecules
    cfg = registry.get("egnn").smoke_config()
    params = egnn_mod.init_params(jax.random.key(0), cfg)
    feat, coords, edges, gids, labels = batched_molecules(
        0, batch=4, n_nodes=6, n_edges=10, d_feat=cfg.d_feat_in,
        n_classes=cfg.n_classes)
    logits, x_out = egnn_mod.forward(
        params, jnp.asarray(feat), jnp.asarray(coords), jnp.asarray(edges),
        cfg, graph_ids=jnp.asarray(gids), n_graphs=4)
    # readout default is node-level for smoke cfg
    assert not bool(jnp.isnan(logits).any())
    # E(3) equivariance: rotate+translate inputs -> coords rotate, h invariant
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    t = rng.standard_normal(3)
    logits2, x_out2 = egnn_mod.forward(
        params, jnp.asarray(feat), jnp.asarray(coords @ q.T + t),
        jnp.asarray(edges), cfg, graph_ids=jnp.asarray(gids), n_graphs=4)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(x_out @ q.T + t),
                               np.asarray(x_out2), atol=2e-4)


def test_egnn_minibatch_sampler_path():
    from repro.data.graph import NeighborSampler, random_graph
    g = random_graph(1, n_nodes=500, n_edges=3000, d_feat=8)
    sampler = NeighborSampler(g.edge_index, 500)
    rng = np.random.default_rng(0)
    block = sampler.sample(np.arange(32), (5, 3), rng)
    # fixed worst-case block size: 32*5 + (32*5)*3
    assert block.shape == (2, 32 * 5 + 32 * 5 * 3)
    valid = block[0] >= 0
    assert valid.any() and (block[1][valid] >= 0).all()
    cfg = registry.get("egnn").smoke_config()
    params = egnn_mod.init_params(jax.random.key(0), cfg)
    logits, _ = egnn_mod.forward(
        params, jnp.asarray(g.node_feat[:, :8]), jnp.asarray(g.coords),
        jnp.asarray(block), cfg)
    assert not bool(jnp.isnan(logits).any())


def test_dlrm_smoke_train():
    from repro.data.recsys import CTRSpec, CTRStream
    cfg = registry.get("dlrm-rm2").smoke_config()
    params = rs.dlrm_init(jax.random.key(0), cfg)
    stream = CTRStream(CTRSpec(n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
                               vocab=cfg.vocab, multi_hot=cfg.multi_hot))
    b = stream.batch(0, 64)
    out = rs.dlrm_forward(params, jnp.asarray(b["dense"]),
                          jnp.asarray(b["sparse"]), cfg)
    assert out.shape == (64,) and not bool(jnp.isnan(out).any())
    opt = adamw(lr=1e-3)

    def loss_fn(p, batch):
        logits = rs.dlrm_forward(p, batch["dense"], batch["sparse"], cfg)
        l = batch["label"]
        return jnp.mean(jnp.maximum(logits, 0) - logits * l
                        + jnp.log1p(jnp.exp(-jnp.abs(logits)))), {}

    step = jax.jit(make_train_step(loss_fn, opt))
    state = {"params": params, "opt": opt.init(params)}
    losses = []
    for i in range(20):
        bb = jax.tree.map(jnp.asarray, stream.batch(i, 64))
        state, m = step(state, bb)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0] + 0.1


def test_xdeepfm_smoke():
    cfg = registry.get("xdeepfm").smoke_config()
    params = rs.xdeepfm_init(jax.random.key(0), cfg)
    idx = jax.random.randint(jax.random.key(1), (32, cfg.n_sparse, 1), 0,
                             cfg.vocab)
    out = rs.xdeepfm_forward(params, idx, cfg)
    assert out.shape == (32,) and not bool(jnp.isnan(out).any())


@pytest.mark.parametrize("arch", ["sasrec", "bert4rec"])
def test_seqrec_smoke(arch):
    cfg = registry.get(arch).smoke_config()
    params = rs.seqrec_init(jax.random.key(0), cfg)
    items = jax.random.randint(jax.random.key(1), (8, cfg.max_len), 0,
                               cfg.vocab)
    items = items.at[:, -3:].set(-1)  # ragged tails
    hidden = rs.seqrec_encode(params, items, cfg)
    assert hidden.shape == (8, cfg.max_len, cfg.embed_dim)
    assert not bool(jnp.isnan(hidden).any())
    repr_ = rs.seqrec_session_repr(params, items, cfg)
    scores = rs.seqrec_score_candidates(params, repr_)
    assert scores.shape == (8, cfg.vocab)
    # bidirectional vs causal: bert4rec position 0 must see future items
    if arch == "bert4rec":
        items2 = items.at[:, 5].set((items[:, 5] + 1) % cfg.vocab)
        h2 = rs.seqrec_encode(params, items2, cfg)
        assert not np.allclose(np.asarray(hidden[:, 0]), np.asarray(h2[:, 0]))


def test_seqrec_bce_trains():
    """Optimization sanity: memorizing one fixed batch must reduce BCE
    (fresh random sessions per step carry no learnable signal at this
    scale, so convergence-on-stream is not the right assertion)."""
    from repro.data.recsys import SessionStream
    cfg = registry.get("sasrec").smoke_config()
    params = rs.seqrec_init(jax.random.key(0), cfg)
    stream = SessionStream(cfg.vocab, cfg.max_len, seed=3)
    opt = adamw(lr=3e-3, warmup=1)

    def loss_fn(p, batch):
        return rs.seqrec_bce_loss(p, batch["items"], batch["pos"],
                                  batch["neg"], cfg), {}

    step = jax.jit(make_train_step(loss_fn, opt))
    state = {"params": params, "opt": opt.init(params)}
    batch = jax.tree.map(jnp.asarray, stream.batch(0, 32))
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_grad_accumulation_matches_full_batch():
    """Property: accum_steps=4 == accum_steps=1 on the same data (adamw)."""
    cfg = registry.get("star-encoder").smoke_config()
    params = tf.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    outs = []
    for accum in (1, 4):
        opt = adamw(lr=1e-2, warmup=1)
        step = jax.jit(make_lm_train_step(cfg, opt, accum_steps=accum,
                                          remat="none"))
        st = {"params": params, "opt": opt.init(params)}
        st, _ = step(st, batch)
        outs.append(st["params"]["embed"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=2e-5)
