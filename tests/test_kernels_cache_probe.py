"""Interpret-mode validation of the fused LowQuality probe kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_probe.ops import cache_probe
from repro.kernels.cache_probe.ref import probe_ref

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _case(seed, qmax, d, n):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((qmax, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    psi = rng.standard_normal(d).astype(np.float32)
    psi /= np.linalg.norm(psi)
    radius = rng.uniform(0.2, 1.2, qmax).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(psi), jnp.asarray(radius),
            jnp.asarray(n, jnp.int32))


@pytest.mark.parametrize("qmax,d,n", [(64, 769, 5), (16, 128, 16),
                                      (8, 64, 0), (33, 200, 12)])
@pytest.mark.parametrize("eps", [0.0, 0.04, 0.5])
def test_probe_matches_ref(qmax, d, n, eps):
    q, psi, radius, nq = _case(qmax + d, qmax, d, n)
    hit_k, r_k, i_k = cache_probe(q, psi, radius, nq, eps, interpret=True)
    hit_r, r_r, i_r = probe_ref(q, psi, radius, nq, eps)
    assert bool(hit_k) == bool(hit_r)
    if n > 0:
        np.testing.assert_allclose(float(r_k), float(r_r), rtol=1e-5,
                                   atol=1e-5)
        assert int(i_k) == int(i_r)
    else:
        assert int(i_k) == -1


def test_probe_agrees_with_core_cache():
    from repro.core.cache import CacheConfig, MetricCache
    from repro.core.metric_index import MetricIndex
    rng = np.random.default_rng(3)
    idx = MetricIndex(jnp.asarray(rng.standard_normal((500, 64)), jnp.float32))
    cache = MetricCache(CacheConfig(capacity=256, dim=idx.dim, max_queries=8))
    for i in range(3):
        qq = idx.transform_queries(jnp.asarray(
            rng.standard_normal(64), jnp.float32))
        res = idx.search(qq[None], 50)
        cache.insert(qq, res.distances[0, -1], idx.dequantized()[res.ids[0]],
                     res.ids[0])
    psi = idx.transform_queries(jnp.asarray(rng.standard_normal(64),
                                            jnp.float32))
    pr = cache.probe(psi)
    st = cache.state
    hit_k, r_k, i_k = cache_probe(st.q_emb, psi, st.q_radius, st.n_queries,
                                  cache.cfg.epsilon, interpret=True)
    assert bool(hit_k) == bool(pr.hit)
    np.testing.assert_allclose(float(r_k), float(pr.r_hat), rtol=1e-5,
                               atol=1e-5)
    assert int(i_k) == int(pr.nearest_q)
