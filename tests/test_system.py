"""End-to-end system tests: conversational engine + router fault tolerance,
checkpoint/restart, elastic meshes, data-pipeline determinism."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metric_index import MetricIndex
from repro.data.conversations import WorldConfig, make_world

jax.config.update("jax_platform_name", "cpu")

SMALL_WORLD = WorldConfig(n_topics=6, docs_per_topic=400, n_background=2000,
                          dim=128, subspace_dim=8, turns=6,
                          n_conversations=4, doc_sigma=0.6, query_sigma=0.12,
                          drift_sigma=0.16, subtopic_prob=0.35,
                          subtopic_sigma=0.75, seed=3)


@pytest.fixture(scope="module")
def world():
    return make_world(SMALL_WORLD)


@pytest.fixture(scope="module")
def index(world):
    return MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))


# ------------------------------------------------------- Algorithm 1 e2e
@pytest.mark.slow
def test_dynamic_cache_end_to_end(world, index):
    from repro.core.conversation import ConversationalSearcher
    s = ConversationalSearcher(index=index, k=10, k_c=150, epsilon=0.04,
                               measure_coverage=True)
    hits, covs = [], []
    for conv in world.conversations:
        s.start_conversation()
        qt = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))
        for t in range(conv.queries.shape[0]):
            rec = s.answer(qt[t])
            covs.append(rec.coverage)
            if t:
                hits.append(rec.hit)
    assert np.mean(covs) > 0.85          # paper: cov10 0.89-0.96
    assert 0.2 < np.mean(hits) <= 1.0    # real reuse happens


# ----------------------------------------------- router fault tolerance
def _make_shards(index, n_shards, delays=None, fail=()):
    """Split the corpus into host-side shard callables with fault injection."""
    import numpy as np
    from repro.serve.router import ShardAnswer
    docs = np.asarray(index.doc_emb[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        lo, hi = bounds[i], bounds[i + 1]
        d, did = docs[lo:hi], ids[lo:hi]

        def shard(queries, k, d=d, did=did, i=i):
            if i in fail:
                raise RuntimeError(f"shard {i} down")
            if delays and delays.get(i):
                time.sleep(delays[i])
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def test_router_merge_matches_exact(world, index):
    from repro.serve.router import ShardedRouter
    rng = np.random.default_rng(0)
    q = np.asarray(index.transform_queries(
        jnp.asarray(rng.standard_normal((3, world.cfg.dim)), jnp.float32)))
    with ShardedRouter(_make_shards(index, 4), deadline_s=10) as router:
        ans, degraded = router.search(q, 20)
    assert not degraded
    exact = index.search(jnp.asarray(q), 20)
    np.testing.assert_array_equal(ans.ids, np.asarray(exact.ids))


def test_router_hedges_stragglers_and_degrades(world, index):
    from repro.serve.router import ShardedRouter
    rng = np.random.default_rng(1)
    q = np.asarray(index.transform_queries(
        jnp.asarray(rng.standard_normal((2, world.cfg.dim)), jnp.float32)))
    # shard 1 is a permanent straggler; shard 2 hard-fails
    with ShardedRouter(_make_shards(index, 4, delays={1: 5.0}, fail={2}),
                       deadline_s=0.5, hedge_after_s=0.1) as router:
        ans, degraded = router.search(q, 10)
        assert degraded
        assert router.stats.hedges >= 1 and router.stats.failures >= 1
        assert ans.ids.shape == (2, 10)  # merged from surviving shards


def test_router_hedge_winner_merged_once(world, index):
    """A hedged retry and its original can both complete: the first answer
    wins, the loser is discarded (not double-merged) and the router does not
    stall waiting for it."""
    from repro.serve.router import ShardedRouter
    calls = {i: 0 for i in range(3)}
    base = _make_shards(index, 3)

    def slow_first(queries, k, i=1):
        calls[i] += 1
        if calls[i] == 1:
            time.sleep(2.0)       # original stalls; the hedge returns fast
        return base[i](queries, k)

    def counting(i):
        def shard(queries, k, i=i):
            calls[i] += 1
            return base[i](queries, k)
        return shard

    shards = [counting(0), slow_first, counting(2)]
    with ShardedRouter(shards, deadline_s=5.0, hedge_after_s=0.05) as router:
        rng = np.random.default_rng(4)
        q = np.asarray(index.transform_queries(
            jnp.asarray(rng.standard_normal((2, world.cfg.dim)),
                        jnp.float32)))
        t0 = time.monotonic()
        ans, degraded = router.search(q, 12)
        elapsed = time.monotonic() - t0
        assert not degraded and router.stats.hedges == 1
        # the loser (still sleeping 2s) must not hold the search open
        assert elapsed < 1.0, elapsed
        # merged once per shard: ids match the exact search, no repeats
        exact = index.search(jnp.asarray(q), 12)
        np.testing.assert_array_equal(ans.ids, np.asarray(exact.ids))
        for row in ans.ids:
            assert len(set(row.tolist())) == len(row)
        # in-flight duplicate was detected + drained; router stays usable
        assert calls[1] == 2 and router.stats.duplicates >= 1
        ans2, degraded2 = router.search(q, 12)
        assert not degraded2
        np.testing.assert_array_equal(ans2.ids, np.asarray(exact.ids))


def test_degraded_turn_does_not_poison_cache(world, index):
    """Regression: a *degraded* back-end answer (shards missing) carries an
    inflated k_c-th distance.  Recording that (psi, r_a) made the cache
    over-claim coverage: a repeat of the same query would falsely hit.  The
    engine must skip the record, so the repeat goes back to the back-end —
    exactly as an exact turn stream would behave for an unknown region."""
    from repro.serve.engine import ConversationalEngine
    from repro.serve.router import ShardedRouter
    conv = world.conversations[0]
    qt = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))

    # healthy baseline: answering the same query twice is a certain hit
    healthy = ConversationalEngine(
        ShardedRouter(_make_shards(index, 4), deadline_s=5),
        np.asarray(index.doc_emb), dim=index.dim, k=5, k_c=150)
    healthy.start_session()
    healthy.answer(np.asarray(qt[0]))
    assert healthy.answer(np.asarray(qt[0])).hit

    # degraded first turn: shard 2 is down, the answer merges 3/4 shards
    degraded_eng = ConversationalEngine(
        ShardedRouter(_make_shards(index, 4, fail={2}), deadline_s=5),
        np.asarray(index.doc_emb), dim=index.dim, k=5, k_c=150)
    degraded_eng.start_session()
    turn1 = degraded_eng.answer(np.asarray(qt[0]))
    assert turn1.degraded and not turn1.hit
    # no (psi, r_a) record -> no false coverage claim on the repeat
    assert degraded_eng.cache.n_queries == 0
    turn2 = degraded_eng.answer(np.asarray(qt[0]))
    assert not turn2.hit
    # the cached docs were still useful as a fallback corpus
    assert degraded_eng.cache.n_docs > 0


@pytest.mark.slow
def test_concurrent_sessions_through_session_manager(world, index):
    """Concurrent multi-session scenario: S interleaved sessions submitted
    through SessionManager waves must reproduce S independent sequential
    engines turn-for-turn (ids, scores, hit flags, hit rates)."""
    from repro.serve.engine import ConversationalEngine
    from repro.serve.router import ShardedRouter
    from repro.serve.session import BatchedEngine, SessionManager
    S, k, k_c = 4, 8, 120
    doc = np.asarray(index.doc_emb)
    seq_router = ShardedRouter(_make_shards(index, 4), deadline_s=30)
    seq = [ConversationalEngine(seq_router, doc, dim=index.dim, k=k, k_c=k_c)
           for _ in range(S)]
    for e in seq:
        e.start_session()
    eng = BatchedEngine(ShardedRouter(_make_shards(index, 4), deadline_s=30),
                        doc, dim=index.dim, n_sessions=S, k=k, k_c=k_c)
    streams = []
    with SessionManager(eng, window_s=10.0, max_batch=S) as mgr:
        for s in range(S):
            conv = world.conversations[s % len(world.conversations)]
            streams.append(np.asarray(index.transform_queries(
                jnp.asarray(conv.queries, jnp.float32))))
            mgr.open(s)
        turns = streams[0].shape[0]
        for t in range(turns):
            futs = [mgr.submit(s, streams[s][t]) for s in range(S)]
            for s, fut in enumerate(futs):
                got = fut.result(timeout=60)
                ref = seq[s].answer(streams[s][t])
                np.testing.assert_array_equal(ref.ids, got.ids)
                np.testing.assert_array_equal(ref.scores, got.scores)
                assert ref.hit == got.hit
    for s in range(S):
        assert seq[s].hit_rate() == eng.hit_rate(s)
        assert eng.hit_rate(s) > 0.0         # sessions actually reuse work


def test_engine_cache_survives_backend_outage(world, index):
    from repro.serve.engine import ConversationalEngine
    from repro.serve.router import ShardedRouter
    shards = _make_shards(index, 2)
    with ShardedRouter(shards, deadline_s=5) as router:
        eng = ConversationalEngine(router, np.asarray(index.doc_emb),
                                   dim=index.dim, k=5, k_c=100)
        eng.start_session()
        conv = world.conversations[0]
        qt = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))
        eng.answer(np.asarray(qt[0]))                # warm the cache
        # back-end goes down entirely: the cache must still answer
        router.shards = _make_shards(index, 2, fail={0, 1})
        turn = eng.answer(np.asarray(qt[1]))
        assert turn.ids.shape == (5,) and (turn.ids >= 0).all()


# --------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.checkpoint import restore_tree, save_tree
    from repro.checkpoint.manager import latest_step
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)},
            "scalar": jnp.asarray(3)}
    for step in (1, 2, 3, 4):
        save_tree(tree, str(tmp_path), step, keep=2)
    assert latest_step(str(tmp_path)) == 4
    assert not os.path.isdir(tmp_path / "step_1")     # gc'd
    out = restore_tree(tree, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_detects_corruption(tmp_path):
    from repro.checkpoint import restore_tree, save_tree
    tree = {"w": jnp.ones((4, 4))}
    save_tree(tree, str(tmp_path), 1)
    # flip a byte in the leaf file
    leaf = tmp_path / "step_1" / "leaf_00000.npy"
    data = bytearray(leaf.read_bytes())
    data[-1] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError, match="corruption"):
        restore_tree(tree, str(tmp_path))


@pytest.mark.slow
def test_checkpoint_manager_async_and_resume(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), interval=2, keep=2)
    tree = {"p": jnp.zeros((8,))}
    for step in range(1, 6):
        tree = {"p": tree["p"] + 1}
        mgr.maybe_save(step, tree)
    mgr.wait()
    restored, step = mgr.restore_or({"p": jnp.zeros((8,))})
    assert step == 4                                   # last multiple of 2
    np.testing.assert_array_equal(np.asarray(restored["p"]),
                                  np.full((8,), 4.0))


@pytest.mark.slow
def test_train_restart_resumes_identically(tmp_path):
    """Fault-tolerance property: kill after step k, restore, continue — the
    loss trajectory matches an uninterrupted run (stateless data pipeline +
    full state checkpoint)."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import registry
    from repro.data.lm import LMBatchSpec, TokenStream
    from repro.models import transformer as tf
    from repro.train.optimizer import adamw
    from repro.train.step import make_lm_train_step

    cfg = registry.get("star-encoder").smoke_config()
    opt = adamw(lr=1e-3, warmup=1)
    step_fn = jax.jit(make_lm_train_step(cfg, opt, remat="none"))
    stream = TokenStream(LMBatchSpec(global_batch=4, seq_len=16,
                                     vocab_size=cfg.vocab_size))

    def fresh():
        params = tf.init_params(jax.random.key(0), cfg)
        return {"params": params, "opt": opt.init(params)}

    # uninterrupted 6 steps
    state = fresh()
    losses_a = []
    for i in range(6):
        state, m = step_fn(state, stream.batch(i))
        losses_a.append(float(m["loss"]))

    # interrupted at step 3 + restart from checkpoint
    mgr = CheckpointManager(str(tmp_path), interval=1)
    state = fresh()
    for i in range(3):
        state, m = step_fn(state, stream.batch(i))
        mgr.maybe_save(i + 1, state)
    mgr.wait()
    state2, last = mgr.restore_or(fresh())
    assert last == 3
    losses_b = []
    for i in range(last, 6):
        state2, m = step_fn(state2, stream.batch(i))
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5)


# ------------------------------------------------------------- elasticity
def test_elastic_mesh_shapes():
    from repro.launch.mesh import make_elastic_mesh, make_host_mesh
    m = make_host_mesh()
    assert m.shape == {"data": 1, "model": 1}
    m2 = make_elastic_mesh(n_devices=1, model_parallel=16)
    assert m2.devices.size == 1                # degraded to what exists


def test_token_stream_deterministic_across_restart():
    from repro.data.lm import LMBatchSpec, TokenStream
    spec = LMBatchSpec(global_batch=4, seq_len=32, vocab_size=1000, seed=9)
    a = TokenStream(spec).batch(17)
    b = TokenStream(spec).batch(17)            # "restarted" pipeline
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_engine_trims_sentinel_rows_when_cache_short():
    """Regression: with a corpus smaller than k the cache can never hold k
    docs; EngineTurn used to surface the cache's (id -1, score -inf)
    sentinel slots straight into rankings and IR metrics."""
    from repro.serve.engine import ConversationalEngine
    from repro.serve.router import ShardedRouter
    rng = np.random.default_rng(0)
    tiny = MetricIndex(jnp.asarray(rng.standard_normal((3, 16)), jnp.float32))
    with ShardedRouter(_make_shards(tiny, 1), deadline_s=10) as router:
        eng = ConversationalEngine(router, np.asarray(tiny.doc_emb),
                                   dim=tiny.dim, k=10, k_c=3)
        eng.start_session()
        q = tiny.transform_queries(
            jnp.asarray(rng.standard_normal(16), jnp.float32))
        turn = eng.answer(q)
    assert turn.ids.shape == (3,) and turn.scores.shape == (3,)
    assert (turn.ids >= 0).all()
    assert np.isfinite(turn.scores).all()
