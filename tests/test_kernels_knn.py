"""Interpret-mode validation of the fused kNN kernel vs. the pure-jnp oracle.

Shape x dtype sweep per the kernel-testing contract. Tie-handling: scores are
compared with allclose; ids are compared as top-k *sets* scored identically
(argmax tie order may legally differ between kernel and lax.top_k).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.knn.ops import knn_search
from repro.kernels.knn.ref import knn_ref

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _check(docs, queries, k, tile_n=256):
    ids = jnp.arange(docs.shape[0], dtype=jnp.int32)
    s_k, i_k = knn_search(docs, ids, queries, k, tile_n=tile_n, interpret=True)
    s_r, i_r = knn_ref(docs, queries, k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=2e-5, atol=2e-5)
    # id agreement where scores are unique per row
    sk, sr = np.asarray(s_k), np.asarray(s_r)
    ik, ir = np.asarray(i_k), np.asarray(i_r)
    for b in range(sk.shape[0]):
        uniq = np.concatenate([[True], np.abs(np.diff(sr[b])) > 1e-5])
        run_ok = uniq & np.append(uniq[1:], True)  # not part of any tie run
        np.testing.assert_array_equal(ik[b][run_ok], ir[b][run_ok])
        assert set(ik[b]) == set(ir[b]) or np.allclose(sorted(sk[b]), sorted(sr[b]), atol=2e-5)


@pytest.mark.parametrize("n,d,b,k", [
    (1000, 769, 4, 10),       # paper geometry: STAR 768(+1)-d
    (4096, 128, 16, 64),
    (300, 32, 1, 5),          # ragged corpus, single query
    (257, 65, 3, 17),         # nothing aligned
    (512, 256, 8, 128),       # k == tile limit region
])
def test_knn_matches_ref_f32(n, d, b, k):
    rng = np.random.default_rng(n + d + b + k)
    docs = rng.standard_normal((n, d)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    q = rng.standard_normal((b, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    _check(jnp.asarray(docs), jnp.asarray(q), k)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_knn_dtypes(dtype):
    rng = np.random.default_rng(0)
    docs = rng.standard_normal((512, 64)).astype(np.float32)
    q = rng.standard_normal((4, 64)).astype(np.float32)
    ids = jnp.arange(512, dtype=jnp.int32)
    s_k, i_k = knn_search(jnp.asarray(docs, dtype), ids, jnp.asarray(q, dtype),
                          8, tile_n=128, interpret=True)
    s_r, i_r = knn_ref(jnp.asarray(docs, dtype), jnp.asarray(q, dtype), 8)
    # bf16 inputs, f32 accumulate in both paths
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-2, atol=1e-2)
    assert (np.asarray(i_k) == np.asarray(i_r)).mean() > 0.9


def test_knn_k_larger_than_tile():
    """k > tile_n: every tile emits all rows; merge must still be exact."""
    rng = np.random.default_rng(3)
    docs = rng.standard_normal((256, 32)).astype(np.float32)
    q = rng.standard_normal((2, 32)).astype(np.float32)
    ids = jnp.arange(256, dtype=jnp.int32)
    s_k, i_k = knn_search(jnp.asarray(docs), ids, jnp.asarray(q), 100,
                          tile_n=64, interpret=True)
    s_r, i_r = knn_ref(jnp.asarray(docs), jnp.asarray(q), 100)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=2e-5, atol=2e-5)


def test_knn_property_monotone_scores():
    """Property: returned scores are descending and are true inner products."""
    rng = np.random.default_rng(9)
    docs = rng.standard_normal((777, 48)).astype(np.float32)
    q = rng.standard_normal((5, 48)).astype(np.float32)
    ids = jnp.arange(777, dtype=jnp.int32)
    s, i = knn_search(jnp.asarray(docs), ids, jnp.asarray(q), 20, interpret=True)
    s, i = np.asarray(s), np.asarray(i)
    assert (np.diff(s, axis=1) <= 1e-6).all()
    recomputed = np.take_along_axis(q @ docs.T, i, axis=1)
    np.testing.assert_allclose(s, recomputed, rtol=1e-5, atol=1e-5)


def test_metric_index_kernel_path_agrees():
    from repro.core.metric_index import MetricIndex
    rng = np.random.default_rng(4)
    raw = rng.standard_normal((900, 64)).astype(np.float32)
    idx_ref = MetricIndex(jnp.asarray(raw))
    idx_ker = MetricIndex(jnp.asarray(raw), use_kernel=True)
    q = idx_ref.transform_queries(jnp.asarray(rng.standard_normal((6, 64)).astype(np.float32)))
    r1 = idx_ref.search(q, 15)
    r2 = idx_ker.search(q, 15)
    np.testing.assert_allclose(np.asarray(r1.scores), np.asarray(r2.scores),
                               rtol=1e-5, atol=1e-5)
