"""Cross-session shared tier (L2) tests: admission policy, claim TTL,
semantic result reuse, the tiered probe order inside ``BatchedEngine``,
and the tiered wave's kernel-launch / zero-copy contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_ops import probe_batched
from repro.core.shared import SharedTier
from repro.kernels import jaxpr_util
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.session import BatchedEngine

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # L2 rides the L1 kernels: gate with them


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _counting_router(docs, counter):
    """Single-shard exact router over host docs; counts back-end calls —
    the unit-level twin of serve_bench's backend_queries_saved column."""
    ids = np.arange(len(docs))

    def shard(queries, k):
        counter["calls"] += 1
        counter["queries"] += len(queries)
        scores = queries @ docs.T
        top = np.argsort(-scores, axis=1)[:, :k]
        return ShardAnswer(np.take_along_axis(scores, top, axis=1), ids[top])

    return ShardedRouter([shard], deadline_s=30.0)


# ------------------------------------------------------- admission policy
def test_admission_requires_distinct_sessions():
    """One session's answer never enters the shared tier; the same answer
    retrieved by a SECOND distinct session promotes wholesale."""
    tier = SharedTier(dim=64, n_shards=2, capacity=100, max_queries=5,
                      backend="interpret")
    rng = np.random.default_rng(7)
    psi = _unit(rng, (64,))
    emb = _unit(rng, (6, 64))
    ids = np.arange(10, 16)
    tier.tick()
    assert not tier.offer(("a", 1), psi, 0.5, emb, ids)
    assert tier.flush_admissions() == 0
    assert not tier.contains(ids).any()
    # re-offering from the SAME session does not advance the count
    assert not tier.offer(("a", 1), psi, 0.5, emb, ids)
    # ...a second distinct session does, and the whole answer promotes
    assert tier.offer(("b", 1), psi, 0.5, emb, ids)
    assert tier.flush_admissions() == 1
    assert tier.contains(ids).all()
    assert tier.n_promoted == 1 and tier.n_offered == 3


def test_admission_frac_gates_partial_overlap():
    """An answer whose documents are mostly one-session-only stays out even
    when a few of them are globally popular."""
    tier = SharedTier(dim=32, capacity=100, max_queries=5,
                      admission_frac=0.5, backend="interpret")
    rng = np.random.default_rng(8)
    emb = _unit(rng, (10, 32))
    hot, cold = np.arange(3), np.arange(100, 107)
    tier.tick()
    tier.offer(("a", 1), _unit(rng, (32,)), 0.5, emb[:3], hot)
    tier.offer(("b", 1), _unit(rng, (32,)), 0.5, emb[:3], hot)  # hot: 2 sess
    # 3/10 promotable (< admission_frac) -> the mixed answer is rejected
    mixed = np.concatenate([hot, cold])
    assert not tier.offer(("c", 1), _unit(rng, (32,)), 0.5, emb, mixed)


# ------------------------------------------------------------- claim TTL
def test_ttl_expires_claims_but_not_documents():
    """Past ttl_waves the coverage claim stops producing probe hits (its
    ring slot's -inf sentinel is restored) while the promoted documents
    stay resident — embeddings don't go stale, claims do."""
    tier = SharedTier(dim=64, n_shards=2, capacity=100, max_queries=5,
                      ttl_waves=3, admission_sessions=1, backend="interpret")
    rng = np.random.default_rng(9)
    psi = _unit(rng, (64,))
    ids = np.arange(20, 27)
    tier.tick()
    assert tier.offer(("a", 1), psi, 0.5, _unit(rng, (7, 64)), ids)
    tier.flush_admissions()
    shards = tier.route(psi[None])
    pr = tier.probe_rows(jnp.asarray(psi[None]), shards)
    assert bool(np.asarray(pr.hit)[0])        # claim live: probe hits
    for _ in range(4):
        tier.tick()                            # age past ttl_waves
    pr = tier.probe_rows(jnp.asarray(psi[None]), shards)
    assert not bool(np.asarray(pr.hit)[0])    # claim retired...
    assert tier.contains(ids).all()           # ...documents survive


# ------------------------------------------------------ semantic result memo
def test_memo_serves_other_sessions_only():
    tier = SharedTier(dim=32, backend="interpret")
    rng = np.random.default_rng(3)
    psi = _unit(rng, (32,))
    ids = np.arange(9)
    scores = np.linspace(0.9, 0.5, 9).astype(np.float32)
    tier.tick()
    tier.memo_record(("a", 1), psi, ids, scores, radius=0.4)
    # a same-session near-duplicate is the L1 tier's job
    assert tier.memo_lookup(("a", 1), psi) is None
    got = tier.memo_lookup(("b", 1), psi)
    assert got is not None
    g_ids, g_scores, claim = got
    np.testing.assert_array_equal(g_ids, ids)
    np.testing.assert_array_equal(g_scores, scores)
    # delta(psi, psi) = 0 up to the fp32 dot's rounding (sqrt amplifies
    # a 1e-7 cosine error to ~5e-4 in distance)
    assert abs(claim - 0.4) < 2e-3
    # an unrelated query never clears the cosine floor
    assert tier.memo_lookup(("b", 1), _unit(rng, (32,))) is None


def test_memo_claim_is_triangle_corrected():
    """The claim handed to a reusing session is r_a - delta(psi_a, psi) —
    the paper's Eq. 3 bound — never the recorded radius itself."""
    tier = SharedTier(dim=48, memo_sim=0.9, backend="interpret")
    rng = np.random.default_rng(4)
    psi = _unit(rng, (48,))
    tier.tick()
    tier.memo_record(("a", 1), psi, np.arange(5),
                     np.ones(5, np.float32), radius=0.7)
    near = psi + 0.05 * _unit(rng, (48,))
    near = near / np.linalg.norm(near)
    sim = float(near @ psi)
    _, _, claim = tier.memo_lookup(("b", 1), near)
    assert abs(claim - (0.7 - np.sqrt(2.0 - 2.0 * sim))) < 2e-3
    assert claim < 0.7


def test_memo_entries_expire_after_ttl():
    tier = SharedTier(dim=32, ttl_waves=2, backend="interpret")
    rng = np.random.default_rng(5)
    psi = _unit(rng, (32,))
    tier.tick()
    tier.memo_record(("a", 1), psi, np.arange(4),
                     np.ones(4, np.float32), radius=0.3)
    tier.tick()
    assert tier.memo_lookup(("b", 1), psi) is not None
    tier.tick()
    tier.tick()                                # age = 3 > ttl_waves
    assert tier.memo_lookup(("b", 1), psi) is None


# --------------------------------------------- tiered BatchedEngine waves
def test_engine_memo_reuse_cross_session_saves_backend_and_overlaps():
    """A near-duplicate query from ANOTHER session is served from the
    result memo (tier l2_reuse) with zero new back-end calls, and the
    reused ranking stays rank-faithful to fresh retrieval (>= 0.95)."""
    rng = np.random.default_rng(11)
    n, d, k, kc = 400, 48, 10, 50
    docs = _unit(rng, (n, d))
    counter = {"calls": 0, "queries": 0}
    router = _counting_router(docs, counter)
    tier = SharedTier(dim=d, n_shards=2, capacity=1024, backend="ref")
    eng = BatchedEngine(router, docs, dim=d, n_sessions=2, k=k, k_c=kc,
                        backend="ref", shared=tier)
    q0 = _unit(rng, (d,))
    t0 = eng.answer_batch([0], [jnp.asarray(q0)])[0]
    assert t0.tier == "backend" and not t0.hit
    calls_before = counter["calls"]
    q1 = q0 + 0.01 * _unit(rng, (d,))          # cosine >> memo_sim floor
    q1 = q1 / np.linalg.norm(q1)
    t1 = eng.answer_batch([1], [jnp.asarray(q1)])[0]
    assert t1.tier == "l2_reuse" and t1.hit
    assert counter["calls"] == calls_before    # back-end query saved
    assert tier.n_memo_served == 1
    fresh, _ = router.search(q1[None], k)
    overlap = len(set(t1.ids[:k].tolist())
                  & set(fresh.ids[0][:k].tolist())) / k
    assert overlap >= 0.95


def test_engine_l2_shard_hit_cross_session_and_l1_reset_survival():
    """With the memo disabled, a promoted shard claim serves a third
    session straight from L2 (tier l2, no back-end call) — and resetting a
    contributing session's L1 cache evicts nothing from the shared tier."""
    rng = np.random.default_rng(12)
    n, d, kc = 400, 48, 50
    docs = _unit(rng, (n, d))
    counter = {"calls": 0, "queries": 0}
    router = _counting_router(docs, counter)
    # memo_sim > 1 can never fire: isolates the shard-cache path
    tier = SharedTier(dim=d, n_shards=2, capacity=1024, memo_sim=1.5,
                      backend="ref")
    eng = BatchedEngine(router, docs, dim=d, n_sessions=3, k=10, k_c=kc,
                        backend="ref", shared=tier)
    base = _unit(rng, (d,))

    def jitter(scale):
        q = base + scale * _unit(rng, (d,))
        return jnp.asarray(q / np.linalg.norm(q))

    # two distinct sessions retrieve the same topic -> answer promotes
    t0, t1 = eng.answer_batch([0, 1], [jitter(0.01), jitter(0.01)])
    assert t0.tier == t1.tier == "backend"
    assert tier.n_promoted >= 1
    promoted = t0.ids[:10]
    assert tier.contains(promoted).all()
    calls_before = counter["calls"]
    # a THIRD session's compulsory first turn is covered by the shared claim
    t2 = eng.answer_batch([2], [jitter(0.01)])[0]
    assert t2.tier == "l2" and t2.hit
    assert counter["calls"] == calls_before
    assert (t2.ids >= 0).all() and t2.ids.size > 0
    # satellite: recycling the contributing L1 slots leaves L2 intact
    eng.start_session(0)
    eng.start_session(1)
    assert tier.contains(promoted).all()
    assert (np.asarray(eng.cache.state.n_docs)[:2] == 0).all()


def test_engine_tier_counts_and_aggregate_hit_rate():
    rng = np.random.default_rng(13)
    docs = _unit(rng, (300, 32))
    counter = {"calls": 0, "queries": 0}
    router = _counting_router(docs, counter)
    tier = SharedTier(dim=32, n_shards=2, capacity=1024, backend="ref")
    eng = BatchedEngine(router, docs, dim=32, n_sessions=2, k=5, k_c=40,
                        backend="ref", shared=tier)
    assert np.isnan(eng.hit_rate())            # no eligible turns yet
    q = jnp.asarray(_unit(rng, (32,)))
    eng.answer_batch([0, 1], [q, q])           # compulsory misses
    eng.answer_batch([0, 1], [q, q])           # L1 covers both
    counts = eng.tier_counts()
    assert counts["l1"] == 2 and counts["backend"] == 0
    assert sum(counts.values()) == 2           # first turns excluded
    assert eng.hit_rate() == 1.0
    assert eng.hit_rate(0) == 1.0 and eng.hit_rate(1) == 1.0
    assert sum(eng.tier_counts(skip_first=False).values()) == 4


# ------------------------------------- launch-count / zero-copy contracts
def test_l2_probe_trace_is_zero_copy_single_launch():
    """The L2 shard probe rides the SAME cache_probe_batched contract as
    L1: tracing it over gathered shard rows shows one Pallas launch and no
    pad/slice/copy at the stacked payload size."""
    tier = SharedTier(dim=200, n_shards=3, capacity=100, max_queries=5,
                      backend="interpret")
    rng = np.random.default_rng(14)
    psi = jnp.asarray(_unit(rng, (3, 200)))
    sub = tier._gather(np.arange(3))
    payload = 3 * tier.cfg.phys_capacity * tier.cfg.phys_dim
    jx = jax.make_jaxpr(
        lambda st, p: probe_batched(st, p, tier.cfg.epsilon,
                                    backend="interpret",
                                    max_queries=tier.cfg.max_queries))(
        sub, psi)
    assert jaxpr_util.payload_copy_eqns(jx, payload) == []
    assert jaxpr_util.pallas_call_count(jx) == 1


@pytest.mark.slow
def test_tiered_engine_full_miss_wave_is_four_launches(monkeypatch):
    """Acceptance (ISSUE 7): on the kernel tier a full-miss TIERED wave is
    exactly FOUR Pallas launches — L1 probe -> L2 probe -> miss-search ->
    fused insert+query — one more than the L1-only contract (asserted in
    test_kernel_equivalence).  A follow-up memo-reuse wave adds NO search
    launch: L1 probe -> fused insert+query -> the admission flush its
    second-session vote triggers."""
    import jax.experimental.pallas as plmod

    from repro.dist.retrieval import DeviceShard

    rng = np.random.default_rng(15)
    n, d, s = 300, 48, 4
    docs = _unit(rng, (n, d))
    shard = DeviceShard(jnp.asarray(docs), jnp.arange(n, dtype=jnp.int32),
                        backend="interpret")
    router = ShardedRouter([shard], deadline_s=120.0)
    tier = SharedTier(dim=d, n_shards=2, capacity=128, max_queries=8,
                      backend="interpret")
    eng = BatchedEngine(router, docs, dim=d, n_sessions=s + 1, k=5, k_c=17,
                        capacity=64, backend="interpret", shared=tier)

    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)

    qs = _unit(rng, (s, d))
    # pallas_call counting happens at TRACE time: drop the process-wide jit
    # cache so the count can't depend on shapes earlier tests compiled
    jax.clear_caches()
    calls["n"] = 0
    turns = eng.answer_batch(list(range(s)), [jnp.asarray(q) for q in qs])
    assert all(t.tier == "backend" for t in turns)
    assert calls["n"] == 4, f"tiered miss wave traced {calls['n']} launches"

    # a new session near-duplicating session 0's query: memo reuse skips
    # both probes-beyond-L1 and the back-end search entirely
    q = qs[0] + 0.01 * _unit(rng, (d,))
    q = q / np.linalg.norm(q)
    jax.clear_caches()
    calls["n"] = 0
    turn = eng.answer_batch([s], [jnp.asarray(q)])[0]
    assert turn.tier == "l2_reuse"
    assert calls["n"] == 3, f"reuse wave traced {calls['n']} launches"
