"""Batched serving tests: fn-mode scheduler waves, short-merge padding,
BatchedEngine equivalence to the sequential engine, SessionManager waves."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metric_index import MetricIndex
from repro.data.conversations import WorldConfig, make_world
from repro.serve.engine import ConversationalEngine
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.session import BatchedEngine, SessionManager

jax.config.update("jax_platform_name", "cpu")

WORLD = WorldConfig(n_topics=6, docs_per_topic=300, n_background=1500,
                    dim=96, subspace_dim=8, turns=5, n_conversations=6,
                    doc_sigma=0.6, query_sigma=0.12, drift_sigma=0.16,
                    subtopic_prob=0.35, subtopic_sigma=0.75, seed=5)


@pytest.fixture(scope="module")
def world():
    return make_world(WORLD)


@pytest.fixture(scope="module")
def index(world):
    return MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))


def make_shards(index, n_shards, fail=()):
    docs = np.asarray(index.doc_emb[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did, i=i):
            if i in fail:
                raise RuntimeError(f"shard {i} down")
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def _streams(world, index, n_sessions):
    convs = world.conversations
    return [np.asarray(index.transform_queries(
        jnp.asarray(convs[s % len(convs)].queries, jnp.float32)))
        for s in range(n_sessions)]


# -------------------------------------------------- fn-mode scheduler waves
def _fn_sched(fn, max_wave, window_s):
    """Fixed-window fn-mode scheduler — the contract the removed
    MicroBatcher shim delegated to."""
    return ContinuousScheduler(fn=fn, max_wave=max_wave, window_s=window_s,
                               adaptive=False, overlap=False)


def test_fn_mode_full_wave_flushes_inline():
    calls = []

    def fn(items):
        calls.append(list(items))
        return [x * 10 for x in items]

    sched = _fn_sched(fn, max_wave=3, window_s=60.0)   # window can't fire
    futs = [sched.submit(i) for i in range(3)]
    assert [f.result(timeout=1) for f in futs] == [0, 10, 20]
    assert calls == [[0, 1, 2]]


def test_fn_mode_window_flushes_stragglers():
    """A lone request below max_wave must still complete within ~window_s."""
    sched = _fn_sched(lambda items: [x + 1 for x in items],
                      max_wave=64, window_s=0.05)
    t0 = time.monotonic()
    fut = sched.submit(41)
    assert fut.result(timeout=2) == 42
    assert time.monotonic() - t0 < 1.0


def test_fn_mode_routes_results_to_submitters():
    sched = _fn_sched(lambda items: [x * x for x in items],
                      max_wave=4, window_s=0.02)
    futs = {x: sched.submit(x) for x in (3, 5, 7)}       # below max_wave
    for x, fut in futs.items():
        assert fut.result(timeout=2) == x * x


def test_fn_mode_exception_fails_all_waiters():
    def boom(items):
        raise RuntimeError("backend exploded")

    sched = _fn_sched(boom, max_wave=2, window_s=60.0)
    f1, f2 = sched.submit(1), sched.submit(2)
    for f in (f1, f2):
        with pytest.raises(RuntimeError, match="exploded"):
            f.result(timeout=1)


def test_fn_mode_exception_result_fails_only_its_waiter():
    """A per-item exception *result* routes to its own submitter; the rest
    of the wave still succeeds (per-session back-end failures)."""
    def fn(items):
        return [ValueError(f"bad {x}") if x < 0 else x * 2 for x in items]

    sched = _fn_sched(fn, max_wave=3, window_s=60.0)
    f1, f2, f3 = sched.submit(1), sched.submit(-5), sched.submit(3)
    assert f1.result(timeout=1) == 2 and f3.result(timeout=1) == 6
    with pytest.raises(ValueError, match="bad -5"):
        f2.result(timeout=1)


def test_fn_mode_serializes_wave_execution():
    """Overlapping flushes (timer vs wave-full) must not run fn
    concurrently — a stateful fn (a BatchedEngine wave) is not re-entrant."""
    import threading
    active, overlaps = [0], [0]
    lock = threading.Lock()

    def fn(items):
        with lock:
            active[0] += 1
            overlaps[0] = max(overlaps[0], active[0])
        time.sleep(0.05)
        with lock:
            active[0] -= 1
        return items

    sched = _fn_sched(fn, max_wave=2, window_s=0.01)
    futs = [sched.submit(i) for i in range(7)]   # mixes full + timer flushes
    for f in futs:
        f.result(timeout=5)
    assert overlaps[0] == 1


def test_microbatcher_shim_is_gone():
    """The one-release deprecation shim is removed: neither the scheduler
    module nor the old router import path exports MicroBatcher anymore
    (migration note in docs/architecture.md)."""
    import repro.serve as serve_pkg
    import repro.serve.router as router_mod
    import repro.serve.scheduler as sched_mod
    for mod in (sched_mod, router_mod, serve_pkg):
        assert not hasattr(mod, "MicroBatcher")


# ------------------------------------------------------- short-merge guard
def test_merge_pads_short_answers_to_k():
    parts = [ShardAnswer(np.asarray([[0.9, 0.1]]), np.asarray([[4, 7]])),
             ShardAnswer(np.asarray([[0.5]]), np.asarray([[2]]))]
    ans = ShardedRouter._merge(parts, k=6)
    assert ans.ids.shape == (1, 6) and ans.scores.shape == (1, 6)
    np.testing.assert_array_equal(ans.ids[0], [4, 2, 7, -1, -1, -1])
    assert np.isneginf(ans.scores[0, 3:]).all()


def test_engine_radius_guarded_on_short_merge(world, index):
    """k_c larger than the corpus: the merge is sentinel-padded and r_a must
    come from the last real doc, not the -inf pad (which would make every
    later probe a false hit via an infinite radius)."""
    router = ShardedRouter(make_shards(index, 2), deadline_s=10)
    eng = ConversationalEngine(router, np.asarray(index.doc_emb),
                               dim=index.dim, k=5, k_c=index.n_docs + 50)
    eng.start_session()
    qt = _streams(world, index, 1)[0]
    turn = eng.answer(qt[0])
    assert not turn.degraded
    radius = float(np.asarray(eng.cache.state.q_radius[0]))
    assert np.isfinite(radius) and radius <= 2.0          # max unit-sphere gap
    assert eng.cache.n_docs == index.n_docs               # pads never cached


# ------------------------------------------- BatchedEngine == sequential
@pytest.mark.slow
def test_batched_engine_bit_identical_to_sequential_loop(world, index):
    S, T, k, k_c = 6, 5, 10, 120
    doc = np.asarray(index.doc_emb)
    seq_router = ShardedRouter(make_shards(index, 4), deadline_s=30)
    seq = [ConversationalEngine(seq_router, doc, dim=index.dim, k=k, k_c=k_c)
           for _ in range(S)]
    bat = BatchedEngine(ShardedRouter(make_shards(index, 4), deadline_s=30),
                        doc, dim=index.dim, n_sessions=S, k=k, k_c=k_c)
    streams = _streams(world, index, S)
    for s in range(S):
        seq[s].start_session()
        bat.start_session(s)
    for t in range(T):
        wave = bat.answer_batch(list(range(S)), [streams[s][t] for s in range(S)])
        for s in range(S):
            ref = seq[s].answer(streams[s][t])
            np.testing.assert_array_equal(ref.ids, wave[s].ids)
            np.testing.assert_array_equal(ref.scores, wave[s].scores)
            assert ref.hit == wave[s].hit and ref.degraded == wave[s].degraded
    # cache states match leaf-for-leaf (q_radius to BLAS batch-vs-row noise:
    # the radii derive from router *scores*, and NumPy GEMM results differ
    # in the last ulp between batch sizes)
    ref_state = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[e.cache.state for e in seq])
    for name, a, b in zip(type(bat.cache.state)._fields, ref_state,
                          bat.cache.state):
        if name == "q_radius":
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"leaf {name}")
    for s in range(S):
        assert seq[s].hit_rate() == bat.hit_rate(s)


@pytest.mark.slow
def test_batched_engine_partial_waves_match_sequential(world, index):
    """Waves smaller than n_sessions are padded to bucket sizes; the real
    rows must still reproduce the sequential engines exactly."""
    S, k, k_c = 5, 8, 100
    doc = np.asarray(index.doc_emb)
    seq_router = ShardedRouter(make_shards(index, 3), deadline_s=30)
    seq = [ConversationalEngine(seq_router, doc, dim=index.dim, k=k, k_c=k_c)
           for _ in range(S)]
    bat = BatchedEngine(ShardedRouter(make_shards(index, 3), deadline_s=30),
                        doc, dim=index.dim, n_sessions=S, k=k, k_c=k_c)
    streams = _streams(world, index, S)
    for s in range(S):
        seq[s].start_session()
        bat.start_session(s)
    # waves of 3 then 2 sessions per turn (bucket-padded to 4 and 2)
    for t in range(4):
        for group in ([0, 1, 2], [3, 4]):
            wave = bat.answer_batch(group, [streams[s][t] for s in group])
            for s, got in zip(group, wave):
                ref = seq[s].answer(streams[s][t])
                np.testing.assert_array_equal(ref.ids, got.ids)
                np.testing.assert_array_equal(ref.scores, got.scores)
                assert ref.hit == got.hit


def test_batched_engine_outage_fails_only_empty_sessions(world, index):
    """Total back-end failure: a warm session's turn still answers from its
    cache, while a fresh (empty-cache) session in the same wave fails alone
    — mirroring the per-session TimeoutError of the sequential loop."""
    router = ShardedRouter(make_shards(index, 2), deadline_s=10)
    eng = BatchedEngine(router, np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=100)
    streams = _streams(world, index, 2)
    eng.start_session(0)
    eng.start_session(1)
    eng.answer_batch([0], [streams[0][0]])      # warm only session 0
    router.shards = make_shards(index, 2, fail={0, 1})
    wave = eng.answer_batch([0, 1], [streams[0][1], streams[1][0]])
    from repro.serve.engine import EngineTurn
    assert isinstance(wave[0], EngineTurn) and wave[0].ids.shape == (5,)
    assert wave[0].degraded or wave[0].hit
    assert isinstance(wave[1], TimeoutError)
    assert len(eng.turns[1]) == 0               # failed turn never recorded
    # a wave where every member is an empty-cache miss still raises
    with pytest.raises(TimeoutError):
        eng.answer_batch([1], [streams[1][0]])


def test_batched_engine_cache_survives_backend_outage(world, index):
    S = 3
    router = ShardedRouter(make_shards(index, 2), deadline_s=10)
    eng = BatchedEngine(router, np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=S, k=5, k_c=100)
    streams = _streams(world, index, S)
    for s in range(S):
        eng.start_session(s)
    eng.answer_batch(list(range(S)), [streams[s][0] for s in range(S)])
    router.shards = make_shards(index, 2, fail={0, 1})    # total outage
    wave = eng.answer_batch(list(range(S)), [streams[s][1] for s in range(S)])
    for s, turn in enumerate(wave):
        if not turn.hit:
            assert turn.degraded
        assert turn.ids.shape == (5,) and (turn.ids >= 0).all()


def test_batched_engine_rejects_duplicate_sessions_in_wave(world, index):
    router = ShardedRouter(make_shards(index, 2), deadline_s=10)
    eng = BatchedEngine(router, np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=50)
    q = _streams(world, index, 1)[0][0]
    with pytest.raises(ValueError, match="one turn per session"):
        eng.answer_batch([0, 0], [q, q])


# ----------------------------------------------------------- SessionManager
@pytest.mark.slow
def test_session_manager_waves_match_sequential(world, index):
    S, T, k, k_c = 4, 4, 8, 100
    doc = np.asarray(index.doc_emb)
    seq_router = ShardedRouter(make_shards(index, 3), deadline_s=30)
    seq = [ConversationalEngine(seq_router, doc, dim=index.dim, k=k, k_c=k_c)
           for _ in range(S)]
    for e in seq:
        e.start_session()
    eng = BatchedEngine(ShardedRouter(make_shards(index, 3), deadline_s=30),
                        doc, dim=index.dim, n_sessions=S, k=k, k_c=k_c)
    streams = _streams(world, index, S)
    with SessionManager(eng, window_s=10.0, max_batch=S) as mgr:  # flush full
        for s in range(S):
            mgr.open(f"user-{s}")
        for t in range(T):
            futs = [mgr.submit(f"user-{s}", streams[s][t]) for s in range(S)]
            for s, fut in enumerate(futs):
                turn = fut.result(timeout=30)
                ref = seq[s].answer(streams[s][t])
                np.testing.assert_array_equal(ref.ids, turn.ids)
                np.testing.assert_array_equal(ref.scores, turn.scores)
                assert ref.hit == turn.hit


def test_session_manager_splits_same_session_turns(world, index):
    """Two turns of one session submitted into one wave must execute in
    arrival order (sub-waves), not collide in a single batched call."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=80)
    qa = _streams(world, index, 1)[0]
    with SessionManager(eng, window_s=10.0, max_batch=3) as mgr:
        mgr.open("a")
        mgr.open("b")
        f1 = mgr.submit("a", qa[0])
        f2 = mgr.submit("b", qa[0])
        f3 = mgr.submit("a", qa[1])        # same session, same wave -> split
        t1, t2, t3 = (f.result(timeout=30) for f in (f1, f2, f3))
    assert not t1.hit                       # compulsory first miss
    assert len(eng.turns[0]) == 2           # both turns landed, in order
    assert eng.turns[0][0] is t1 and eng.turns[0][1] is t3


def test_session_manager_shutdown_and_context_manager(world, index):
    """Satellite (ISSUE 7): leaving the with-block (or calling shutdown())
    stops the scheduler's worker thread — later submits raise
    instead of stranding a Future — and shutdown is idempotent."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=50)
    q = _streams(world, index, 1)[0]
    with SessionManager(eng, window_s=0.02, max_batch=8) as mgr:
        mgr.open("u")
        turn = mgr.submit("u", q[0]).result(timeout=30)
        assert turn.ids.shape == (5,)
    with pytest.raises(RuntimeError, match="closed"):
        mgr.submit("u", q[1])
    mgr.shutdown()                              # idempotent


def test_session_manager_close_unknown_key_names_key(index):
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=1, k=5, k_c=50)
    with SessionManager(eng) as mgr:
        with pytest.raises(KeyError, match="unknown session key 'ghost'"):
            mgr.close("ghost")


def test_batched_engine_aggregate_hit_rate(world, index):
    """Satellite (ISSUE 7): hit_rate() with no argument aggregates across
    every session's eligible turns — well-defined as soon as ANY session
    has a second turn, where the old per-session mean was NaN-prone."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=80)
    assert np.isnan(eng.hit_rate())
    streams = _streams(world, index, 2)
    eng.answer_batch([0, 1], [streams[0][0], streams[1][0]])
    assert np.isnan(eng.hit_rate())             # only compulsory turns so far
    eng.answer_batch([0], [streams[0][0]])      # repeat -> certain L1 hit
    assert eng.hit_rate() == eng.hit_rate(0) == 1.0
    assert np.isnan(eng.hit_rate(1))            # single-turn session
    per = [eng.hit_rate(s) for s in range(2)]
    agg = float(np.mean([h for turns in eng.turns
                         for h in [t.hit for t in turns[1:]]]))
    assert eng.hit_rate() == agg
    assert per[0] == agg                        # session 1 contributes none


def test_session_manager_window_flush_and_slot_reuse(world, index):
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=1, k=5, k_c=50)
    q = _streams(world, index, 1)[0]
    with SessionManager(eng, window_s=0.05, max_batch=8) as mgr:
        mgr.open("x")
        fut = mgr.submit("x", q[0])         # below max_batch: window flushes
        assert fut.result(timeout=10).ids.shape == (5,)
        mgr.close("x")
        assert mgr.active_sessions == 0
        slot = mgr.open("y")                # slot recycled, cache reset
        assert slot == 0 and eng.cache.n_docs[0] == 0
        with pytest.raises(RuntimeError, match="no free session slots"):
            mgr._free.clear() or mgr.open("z")


def test_batched_engine_trims_sentinel_rows_when_cache_short(index):
    """Regression twin of the sequential-engine test: a wave answered from
    caches holding fewer than k docs must not surface sentinel slots."""
    rng = np.random.default_rng(2)
    tiny = MetricIndex(jnp.asarray(rng.standard_normal((4, 24)), jnp.float32))
    router = ShardedRouter(make_shards(tiny, 1), deadline_s=10)
    eng = BatchedEngine(router, np.asarray(tiny.doc_emb), dim=tiny.dim,
                        n_sessions=4, k=9, k_c=4)
    qs = np.asarray(tiny.transform_queries(
        jnp.asarray(rng.standard_normal((2, 24)), jnp.float32)))
    for turn in eng.answer_batch([0, 1], list(qs)):
        assert turn.ids.shape == (4,) and (turn.ids >= 0).all()
        assert np.isfinite(turn.scores).all()
