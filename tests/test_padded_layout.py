"""The pre-padded physical cache layout (``repro.core.layout``).

Four claims, each tested directly:

  1. extent math — the physical extents are the documented roundings and
     the wave tile derived from a physical capacity equals the tile
     derived from the logical one (so wrappers can read shapes alone);
  2. init sentinels — padded doc columns / ring slots hold the empty-slot
     sentinels from birth, and NO op ever rewrites them (LRU stamps of
     padded columns stay 0 across insert waves on every tier);
  3. layout equivalence — the ops are layout-agnostic: a pre-padded state
     and a hand-built LOGICAL-extent state (the pre-padding layout) give
     turn-identical probe / insert / query behaviour across awkward
     extents, storage dtypes, eviction policies, and ring wraps — and the
     ref and interpret kernel tiers agree on the padded layout;
  4. zero-copy — a traced kernel-tier wave contains no pad / slice /
     copy of the stacked (S, capacity, dim) payload outside its Pallas
     launches, and stays the contracted launch count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout, quant
from repro.core.cache import (CacheConfig, CacheState, MetricCache,
                              init_batched_cache, init_cache, insert,
                              insert_query_batched, probe, probe_batched,
                              query, reset_sessions)
from repro.kernels import jaxpr_util

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


# ----------------------------------------------------------- 1. extent math
def test_phys_extent_math():
    assert [layout.wave_tile(c) for c in (1, 7, 8, 100, 127, 128, 512, 513)] \
        == [8, 8, 8, 128, 128, 128, 512, 512]
    assert [layout.phys_capacity(c) for c in (1, 100, 127, 128, 513)] \
        == [8, 128, 128, 128, 1024]
    assert [layout.phys_dim(d) for d in (32, 128, 200, 769)] \
        == [128, 128, 256, 896]
    assert [layout.phys_queries(q) for q in (1, 8, 33, 64)] == [8, 8, 40, 64]


def test_wave_tile_stable_under_phys_rounding():
    """Wrappers derive the tile from the PHYSICAL shape; it must equal the
    tile of the logical capacity or the grid geometry would drift."""
    for c in (1, 3, 8, 100, 127, 128, 200, 511, 512, 513, 1000, 4096):
        assert layout.wave_tile(layout.phys_capacity(c)) == layout.wave_tile(c)


def test_cacheconfig_derived_fields():
    cfg = CacheConfig(capacity=100, dim=769, max_queries=33)
    assert (cfg.phys_capacity, cfg.phys_dim, cfg.phys_max_queries) \
        == (128, 896, 40)


# -------------------------------------------------------- 2. init sentinels
@pytest.mark.parametrize("store_dtype", ["fp32", "bf16", "int8"])
def test_init_cache_allocates_physical_extents_with_sentinels(store_dtype):
    cfg = CacheConfig(capacity=100, dim=200, max_queries=5,
                      store_dtype=store_dtype)
    st = init_cache(cfg)
    assert st.doc_emb.shape == (128, 256)
    assert st.q_emb.shape == (8, 256)
    assert st.doc_ids.shape == st.doc_stamp.shape == st.doc_scale.shape \
        == (128,)
    assert st.q_radius.shape == st.q_scale.shape == (8,)
    np.testing.assert_array_equal(np.asarray(st.doc_ids), -1)
    np.testing.assert_array_equal(np.asarray(st.doc_stamp), 0)
    np.testing.assert_array_equal(np.asarray(st.doc_scale), 1.0)
    np.testing.assert_array_equal(np.asarray(st.q_scale), 1.0)
    assert np.isneginf(np.asarray(st.q_radius)).all()
    assert np.asarray(st.doc_emb.astype(jnp.float32)).sum() == 0.0


def _rows(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_padded_columns_survive_insert_waves_untouched(backend):
    """Satellite: padded columns' sentinels — LRU stamps INCLUDED — stay
    bitwise untouched across insert+query waves on both tiers."""
    s, cap, dim, kc, mq = 3, 100, 64, 7, 5
    cfg = CacheConfig(capacity=cap, dim=dim, max_queries=mq)
    state = init_batched_cache(cfg, s)
    rng = np.random.default_rng(0)
    for t in range(4):
        psi = jnp.asarray(_rows(rng, s, dim))
        ids = jnp.asarray(
            rng.integers(0, 500, (s, kc)).astype(np.int32))
        emb = jnp.asarray(_rows(rng, s * kc, dim).reshape(s, kc, dim))
        radius = jnp.asarray(rng.uniform(0.2, 1.0, s).astype(np.float32))
        _out, state, _dropped = insert_query_batched(
            state, cfg, psi, radius, emb, ids, k=4, backend=backend)
    cp, qp = cfg.phys_capacity, cfg.phys_max_queries
    assert cp > cap and qp > mq  # the test only bites with real padding
    np.testing.assert_array_equal(np.asarray(state.doc_ids)[:, cap:], -1)
    np.testing.assert_array_equal(np.asarray(state.doc_stamp)[:, cap:], 0)
    np.testing.assert_array_equal(np.asarray(state.doc_scale)[:, cap:], 1.0)
    assert np.isneginf(np.asarray(state.q_radius)[:, mq:]).all()
    np.testing.assert_array_equal(np.asarray(state.q_scale)[:, mq:], 1.0)
    assert np.asarray(
        state.q_emb.astype(jnp.float32))[:, mq:, :].sum() == 0.0
    # ...and real docs did land
    assert (np.asarray(state.doc_ids)[:, :cap] >= 0).any()


# --------------------------------------------------- 3. layout equivalence
def _logical_state(cfg: CacheConfig) -> CacheState:
    """Hand-build a CacheState at the LOGICAL extents — the pre-padding
    layout.  The scalar ops are layout-agnostic (they mask on the config /
    sentinels, never on leaf shapes), so driving both layouts through the
    same turns must give identical results."""
    store = quant.storage_dtype(cfg.store_dtype)
    return CacheState(
        doc_emb=jnp.zeros((cfg.capacity, cfg.dim), store),
        doc_ids=jnp.full((cfg.capacity,), -1, jnp.int32),
        doc_stamp=jnp.zeros((cfg.capacity,), jnp.int32),
        q_emb=jnp.zeros((cfg.max_queries, cfg.dim), store),
        q_radius=jnp.full((cfg.max_queries,), -jnp.inf, cfg.dtype),
        n_docs=jnp.zeros((), jnp.int32),
        n_queries=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        doc_scale=jnp.ones((cfg.capacity,), jnp.float32),
        q_scale=jnp.ones((cfg.max_queries,), jnp.float32),
    )


AWKWARD = [(1, 32), (100, 769), (127, 33), (128, 128)]


@pytest.mark.slow
@pytest.mark.parametrize("capacity,dim", AWKWARD)
@pytest.mark.parametrize("store_dtype", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("eviction", ["none", "lru", "ball"])
def test_padded_layout_turn_identical_to_logical_layout(
        capacity, dim, store_dtype, eviction):
    """The sweep: padded vs logical layout, turn-by-turn, through probes
    (ring-wrapping max_queries=3), inserts (overflowing capacity=1 cases
    exercise drops and every eviction policy), and top-k queries."""
    cfg = CacheConfig(capacity=capacity, dim=dim, max_queries=3,
                      eviction=eviction, store_dtype=store_dtype)
    padded = init_cache(cfg)
    oracle = _logical_state(cfg)
    rng = np.random.default_rng(capacity * 7 + dim)
    kc, k = 3, min(2, capacity)
    for t in range(5):
        psi = jnp.asarray(_rows(rng, 1, dim)[0])
        pr_p = probe(padded, psi, cfg.epsilon, max_queries=cfg.max_queries)
        pr_o = probe(oracle, psi, cfg.epsilon, max_queries=cfg.max_queries)
        assert bool(pr_p.hit) == bool(pr_o.hit)
        assert int(pr_p.nearest_q) == int(pr_o.nearest_q)
        # scores to float tolerance only: the padded matmul reduces over
        # Dp lanes (zeros past dim), a different XLA reduction shape
        np.testing.assert_allclose(np.asarray(pr_p.r_hat),
                                   np.asarray(pr_o.r_hat),
                                   rtol=1e-6, atol=1e-6)

        ids = jnp.asarray(rng.integers(0, 50, kc).astype(np.int32))
        emb = jnp.asarray(_rows(rng, kc, dim))
        radius = jnp.asarray(rng.uniform(0.2, 1.0), jnp.float32)
        padded, drop_p = insert(padded, cfg, psi, radius, emb, ids)
        oracle, drop_o = insert(oracle, cfg, psi, radius, emb, ids)
        assert int(drop_p) == int(drop_o)
        assert int(padded.n_docs) == int(oracle.n_docs)
        assert int(padded.n_queries) == int(oracle.n_queries)

        (s_p, d_p, i_p, sl_p), padded = query(padded, psi, k)
        (s_o, d_o, i_o, sl_o), oracle = query(oracle, psi, k)
        np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_o))
        np.testing.assert_array_equal(np.asarray(sl_p), np.asarray(sl_o))
        np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_o),
                                   rtol=1e-6, atol=1e-6)

    # final state: every logical leaf slice matches the oracle bitwise
    np.testing.assert_array_equal(
        np.asarray(padded.doc_ids)[:capacity], np.asarray(oracle.doc_ids))
    np.testing.assert_array_equal(
        np.asarray(padded.doc_stamp)[:capacity],
        np.asarray(oracle.doc_stamp))
    np.testing.assert_array_equal(
        np.asarray(padded.doc_emb)[:capacity, :dim],
        np.asarray(oracle.doc_emb))
    np.testing.assert_array_equal(
        np.asarray(padded.q_radius)[:cfg.max_queries],
        np.asarray(oracle.q_radius))
    np.testing.assert_array_equal(
        np.asarray(padded.q_emb)[:cfg.max_queries, :dim],
        np.asarray(oracle.q_emb))


@pytest.mark.slow
@pytest.mark.parametrize("capacity,dim", AWKWARD)
@pytest.mark.parametrize("store_dtype", ["fp32", "bf16", "int8"])
def test_padded_layout_ref_vs_interpret_tiers(capacity, dim, store_dtype):
    """Batched wave on the padded layout: the ref (vmap) and interpret
    (fused Pallas) tiers stay rank-identical across the awkward extents."""
    s, kc, mq = 3, 3, 3
    k = min(2, capacity)
    cfg = CacheConfig(capacity=capacity, dim=dim, max_queries=mq,
                      store_dtype=store_dtype)
    st_ref = init_batched_cache(cfg, s)
    st_ker = init_batched_cache(cfg, s)
    rng = np.random.default_rng(capacity + dim)
    for t in range(4):
        psi = jnp.asarray(_rows(rng, s, dim))
        pr_r = probe_batched(st_ref, psi, cfg.epsilon, backend="ref",
                             max_queries=mq)
        pr_k = probe_batched(st_ker, psi, cfg.epsilon, backend="interpret",
                             max_queries=mq)
        np.testing.assert_array_equal(np.asarray(pr_r.hit),
                                      np.asarray(pr_k.hit))
        np.testing.assert_array_equal(np.asarray(pr_r.nearest_q),
                                      np.asarray(pr_k.nearest_q))

        ids = jnp.asarray(rng.integers(0, 40, (s, kc)).astype(np.int32))
        emb = jnp.asarray(_rows(rng, s * kc, dim).reshape(s, kc, dim))
        radius = jnp.asarray(rng.uniform(0.2, 1.0, s).astype(np.float32))
        do = jnp.asarray(~np.asarray(pr_r.hit))
        (v_r, _, i_r, sl_r), st_ref, dr_r = insert_query_batched(
            st_ref, cfg, psi, radius, emb, ids, k=k, do=do, backend="ref")
        (v_k, _, i_k, sl_k), st_ker, dr_k = insert_query_batched(
            st_ker, cfg, psi, radius, emb, ids, k=k, do=do,
            backend="interpret")
        np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_k))
        np.testing.assert_array_equal(np.asarray(sl_r), np.asarray(sl_k))
        np.testing.assert_allclose(np.asarray(v_r), np.asarray(v_k),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(dr_r), np.asarray(dr_k))
    np.testing.assert_array_equal(np.asarray(st_ref.doc_ids),
                                  np.asarray(st_ker.doc_ids))
    np.testing.assert_array_equal(np.asarray(st_ref.doc_stamp),
                                  np.asarray(st_ker.doc_stamp))
    np.testing.assert_array_equal(np.asarray(st_ref.n_docs),
                                  np.asarray(st_ker.n_docs))


def test_ring_wrapped_probe_padded_layout():
    """A ring wrapped far past max_queries on the padded layout: the probe
    sees exactly the newest LOGICAL records, never a padded slot."""
    dim, mq = 48, 3
    cfg = CacheConfig(capacity=64, dim=dim, max_queries=mq)
    cache = MetricCache(cfg)
    rng = np.random.default_rng(9)
    psis = _rows(rng, 8, dim)
    for i in range(8):
        cache.insert(jnp.asarray(psis[i]), jnp.asarray(0.5, jnp.float32),
                     jnp.asarray(_rows(rng, 2, dim)),
                     jnp.arange(2 * i, 2 * i + 2, dtype=jnp.int32))
    assert cache.n_queries == mq and cache.total_queries == 8
    # newest query self-probes to ~r_a; evicted query 0 does not
    pr = cache.probe(jnp.asarray(psis[7]), epsilon=0.4)
    assert bool(pr.hit) and abs(float(pr.r_hat) - 0.5) < 1e-3
    assert int(pr.nearest_q) < mq  # never a padded ring slot
    pr_old = cache.probe(jnp.asarray(psis[0]), epsilon=0.4)
    assert float(pr_old.r_hat) < 0.5 - 1e-3 and not bool(pr_old.hit)


# ------------------------------------------------------------ 4. zero-copy
def _wave_setup(s=4, capacity=100, dim=200, kc=5, mq=5):
    cfg = CacheConfig(capacity=capacity, dim=dim, max_queries=mq)
    state = init_batched_cache(cfg, s)
    rng = np.random.default_rng(1)
    psi = jnp.asarray(_rows(rng, s, dim))
    ids = jnp.asarray(rng.integers(0, 99, (s, kc)).astype(np.int32))
    emb = jnp.asarray(_rows(rng, s * kc, dim).reshape(s, kc, dim))
    radius = jnp.asarray(rng.uniform(0.2, 1.0, s).astype(np.float32))
    return cfg, state, psi, ids, emb, radius


def test_traced_miss_wave_has_no_payload_copies():
    """Tier-1 guard: the kernel-tier probe and fused insert+query traces
    contain NO pad/slice/copy at the stacked payload size — the zero-copy
    contract — and each stays a single Pallas launch."""
    cfg, state, psi, ids, emb, radius = _wave_setup()
    s = psi.shape[0]
    payload = s * cfg.phys_capacity * cfg.phys_dim  # elements

    jx_probe = jax.make_jaxpr(
        lambda st, p: probe_batched(st, p, cfg.epsilon, backend="interpret",
                                    max_queries=cfg.max_queries))(state, psi)
    assert jaxpr_util.payload_copy_eqns(jx_probe, payload) == []
    assert jaxpr_util.pallas_call_count(jx_probe) == 1

    jx_wave = jax.make_jaxpr(
        lambda st, p, r, e, i: insert_query_batched(
            st, cfg, p, r, e, i, k=4, backend="interpret"))(
        state, psi, radius, emb, ids)
    assert jaxpr_util.payload_copy_eqns(jx_wave, payload) == []
    assert jaxpr_util.pallas_call_count(jx_wave) == 1


def test_wave_moved_bytes_below_payload():
    """The serve_bench metric at test scale: non-launch traffic of a full
    miss wave (probe + insert+query) stays well under ONE stacked-payload
    copy — the pre-padding layout used to move >= 2x payload per wave."""
    cfg, state, psi, ids, emb, radius = _wave_setup()
    s = psi.shape[0]
    payload_bytes = (s * cfg.phys_capacity * cfg.phys_dim
                     * jnp.dtype(jnp.float32).itemsize)
    moved = jaxpr_util.trace_moved_bytes(
        lambda st, p: probe_batched(st, p, cfg.epsilon, backend="interpret",
                                    max_queries=cfg.max_queries),
        state, psi)
    moved += jaxpr_util.trace_moved_bytes(
        lambda st, p, r, e, i: insert_query_batched(
            st, cfg, p, r, e, i, k=4, backend="interpret"),
        state, psi, radius, emb, ids)
    assert moved < payload_bytes, (moved, payload_bytes)


# ------------------------------------------- 5. session-lifecycle resets
def test_reset_sessions_preserves_padded_sentinels():
    """Satellite (ISSUE 7): resetting one L1 session row re-initializes its
    LOGICAL content while the padded extents of EVERY row keep their
    permanent sentinels — and untouched rows stay bitwise identical, so an
    end-of-conversation reset can never perturb a neighbor session."""
    cfg, state, psi, ids, emb, radius = _wave_setup(s=3)
    _out, state, _dropped = insert_query_batched(
        state, cfg, psi, radius, emb, ids, k=4, backend="interpret")
    before = jax.tree_util.tree_map(np.asarray, state)
    state = reset_sessions(state, cfg, jnp.asarray([True, False, False]))
    cap, mq = cfg.capacity, cfg.max_queries
    # the reset row is fully fresh: sentinels across logical AND padded slots
    np.testing.assert_array_equal(np.asarray(state.doc_ids)[0], -1)
    np.testing.assert_array_equal(np.asarray(state.doc_stamp)[0], 0)
    assert np.isneginf(np.asarray(state.q_radius)[0]).all()
    assert int(state.n_docs[0]) == 0 and int(state.n_queries[0]) == 0
    # padded extents of every row still hold the permanent sentinels
    np.testing.assert_array_equal(np.asarray(state.doc_ids)[:, cap:], -1)
    np.testing.assert_array_equal(np.asarray(state.doc_stamp)[:, cap:], 0)
    assert np.isneginf(np.asarray(state.q_radius)[:, mq:]).all()
    # the other sessions' rows are bitwise untouched
    for name, b, a in zip(CacheState._fields, before, state):
        np.testing.assert_array_equal(
            b[1:], np.asarray(a)[1:],
            err_msg=f"reset of row 0 leaked into leaf {name}")


def test_shared_tier_admissions_keep_padded_sentinels():
    """The L2 shard rows are the SAME pre-padded CacheState layout: after
    an admission insert and a TTL expiry pass, the padded extents still
    hold their permanent sentinels (zero-copy launches depend on them)."""
    from repro.core.shared import SharedTier

    dim, cap, mq = 64, 100, 5
    tier = SharedTier(dim=dim, n_shards=2, capacity=cap, max_queries=mq,
                      ttl_waves=2, admission_sessions=1,
                      backend="interpret")
    rng = np.random.default_rng(21)
    psi = _rows(rng, 1, dim)[0]
    tier.tick()
    assert tier.offer(("a", 1), psi, 0.5, _rows(rng, 7, dim), np.arange(7))
    tier.flush_admissions()
    for _ in range(3):
        tier.tick()                    # expire the claim via TTL
    st = tier.state
    assert tier.cfg.phys_capacity > cap and tier.cfg.phys_max_queries > mq
    np.testing.assert_array_equal(np.asarray(st.doc_ids)[:, cap:], -1)
    np.testing.assert_array_equal(np.asarray(st.doc_stamp)[:, cap:], 0)
    np.testing.assert_array_equal(np.asarray(st.doc_scale)[:, cap:], 1.0)
    assert np.isneginf(np.asarray(st.q_radius)[:, mq:]).all()
    np.testing.assert_array_equal(np.asarray(st.q_scale)[:, mq:], 1.0)
    assert np.asarray(st.q_emb.astype(jnp.float32))[:, mq:, :].sum() == 0.0
    # the promoted documents landed in the logical prefix
    assert tier.contains(np.arange(7)).all()
