"""Force a multi-device CPU topology before jax initializes.

Loaded by pytest before any test module imports jax, so every test sees 8
host devices — the distributed-retrieval tests need a >=2-device mesh and
single-device tests are unaffected (jit placement defaults to device 0).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.hostdevices import ensure_host_devices  # noqa: E402

ensure_host_devices(8)
