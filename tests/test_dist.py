"""repro.dist: logical sharding API, spec derivation, and the sharded
back-end retrieval layer (bit-identity with exact_nn on a multi-device mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core import embedding as emb
from repro.core.metric_index import MetricIndex, exact_nn
from repro.dist import retrieval as dr
from repro.dist import sharding as shd
from repro.dist.api import (active_mesh, constrain, data_axes, fit_spec,
                            sharding_rules)
from repro.models import transformer as tf

jax.config.update("jax_platform_name", "cpu")


def test_multi_device_topology():
    """conftest forces 8 host devices; everything below depends on it."""
    assert jax.device_count() >= 2


def _mesh(shape, axes):
    return jax.make_mesh(shape, axes)


def _corpus(n, dim, seed=0, n_dup=0, n_queries=5):
    """Transformed corpus + queries; first n_dup docs duplicated mid-corpus
    so top-k tie-breaking is actually exercised."""
    rng = np.random.default_rng(seed)
    phi = rng.standard_normal((n, dim)).astype(np.float32)
    if n_dup:
        phi[n // 2:n // 2 + n_dup] = phi[:n_dup]
    docs, _ = emb.transform_documents(jnp.asarray(phi))
    q = emb.transform_queries(jnp.asarray(
        rng.standard_normal((n_queries, dim)).astype(np.float32)))
    return docs, jnp.arange(n, dtype=jnp.int32), q


# ----------------------------------------------------------------- dist.api

def test_constrain_identity_without_context():
    x = jnp.ones((4, 8))
    assert constrain(x, "act_bsd") is x
    assert active_mesh() is None


def test_sharding_rules_context_applies_and_fits():
    mesh = _mesh((2, 4), ("data", "model"))
    assert data_axes(mesh) == ("data",)
    rules = {"act_bsd": P("data", None, "model")}
    with sharding_rules(mesh, rules):
        assert active_mesh() is mesh
        y = jax.jit(lambda a: constrain(a, "act_bsd"))(jnp.zeros((4, 8, 16)))
        # batch split 2-way, last dim 4-way
        assert y.addressable_shards[0].data.shape == (2, 8, 4)
        # non-divisible dims: offending axes dropped, no error
        z = jax.jit(lambda a: constrain(a, "act_bsd"))(jnp.zeros((3, 8, 6)))
        assert z.shape == (3, 8, 6)
        # unknown rule name: identity
        w = jnp.zeros((5,))
        assert constrain(w, "no_such_rule") is w
    assert active_mesh() is None


def test_fit_spec_pads_and_drops():
    mesh = _mesh((2, 4), ("data", "model"))
    assert tuple(fit_spec(P("data"), (6, 7), mesh)) == ("data", None)
    assert tuple(fit_spec(P("data", "model"), (6, 7), mesh)) == ("data", None)
    assert fit_spec(P("data", None, None), (6,), mesh) is None


# ------------------------------------------------------------ dist.sharding

def test_param_specs_full_rank_and_divisible():
    mesh = _mesh((2, 4), ("data", "model"))
    cfg = registry.get("star-encoder").full_config()
    shapes = jax.eval_shape(lambda: tf.init_params(jax.random.key(0), cfg))
    specs = shd.param_specs(shapes, mesh)
    assert jax.tree.structure(specs) == jax.tree.structure(
        shapes, is_leaf=lambda x: hasattr(x, "shape"))
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = 0
    for leaf, spec in zip(flat_shapes, flat_specs):
        assert isinstance(spec, P) and len(tuple(spec)) == leaf.ndim
        # every assignment must already fit (param_specs guarantees this)
        assert tuple(fit_spec(spec, leaf.shape, mesh)) == tuple(spec)
        n_sharded += any(e is not None for e in tuple(spec))
    assert n_sharded > 0    # the big matrices actually shard


def test_param_specs_moe_expert_parallel():
    mesh = _mesh((2, 4), ("data", "model"))
    cfg = registry.get("deepseek-v3-671b").smoke_config()
    shapes = jax.eval_shape(lambda: tf.init_params(jax.random.key(0), cfg))
    specs = shd.param_specs(shapes, mesh, min_shard_size=1)
    for gname, group in specs.items():
        if "moe" not in gname:
            continue
        wi = tuple(group["ffn"]["wi"])      # (layers, E, d, 2ff)
        assert wi[1] == "model" or wi[1] is None  # expert dim, if divisible
        if cfg.moe.n_experts % 4 == 0:
            assert wi[1] == "model"


def test_lm_activation_rules_cover_all_constrain_names():
    mesh = _mesh((2, 4), ("data", "model"))
    for arch in ("gemma2-9b", "deepseek-v3-671b"):
        cfg = registry.get(arch).full_config()
        for kind in ("train", "decode"):
            rules = shd.lm_activation_rules(mesh, cfg, kind)
            for name in ("act_bsd", "act_bsf", "act_bshd", "act_bskd",
                         "attn_scores", "kv_cache", "mla_cache",
                         "mla_cache_r", "logits", "moe_buf", "moe_hidden",
                         "moe_out", "act_bfd"):
                assert name in rules and isinstance(rules[name], P)

    class Dummy:     # the recsys stub from launch/cells
        n_heads = 1
        n_kv_heads = 1
        attention = "gqa"

    rules = shd.lm_activation_rules(mesh, Dummy(), "train")
    assert tuple(rules["act_bshd"])[2] is None   # 1 head cannot split 4 ways


def test_forward_under_sharding_rules_matches_unsharded():
    mesh = _mesh((2, 4), ("data", "model"))
    cfg = registry.get("star-encoder").smoke_config()
    params = tf.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    ref = tf.forward(params, tokens, cfg, remat="none")[0]
    rules = shd.lm_activation_rules(mesh, cfg, "train")
    with sharding_rules(mesh, rules):
        out = jax.jit(
            lambda p, t: tf.forward(p, t, cfg, remat="none")[0])(params, tokens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-4)


def test_cells_build_on_host_mesh():
    """The launch layer's cell builders run end-to-end on the dist API
    (eval_shape only — no compile, no allocation)."""
    from repro.launch.cells import build_lm_cell, build_recsys_cell
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    cfg = registry.get("star-encoder").smoke_config()
    cell = build_lm_cell("star-encoder", "train_4k", mesh, cfg_override=cfg)
    assert cell.kind == "train" and cell.rules and cell.in_shardings
    cell = build_lm_cell("star-encoder", "decode_32k", mesh, cfg_override=cfg)
    assert cell.kind == "decode"
    cell = build_recsys_cell("sasrec", "retrieval_cand", mesh)
    assert cell.kind == "retrieval" and callable(cell.fn)


# ----------------------------------------------------------- dist.retrieval

@pytest.mark.parametrize("n", [4096, 5003])
def test_sharded_nn_bit_identical_to_exact(n):
    docs, ids, q = _corpus(n, 32, n_dup=16)
    ref = exact_nn(docs, ids, q, 25)
    meshes = [None,                                     # flat all-device mesh
              _mesh((8,), ("shard",)),
              _mesh((2, 4), ("data", "model"))]         # multi-axis corpus
    for mesh in meshes:
        res = dr.sharded_nn(docs, ids, q, 25, mesh=mesh, chunk=512)
        np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
        np.testing.assert_allclose(np.asarray(res.scores),
                                   np.asarray(ref.scores), rtol=1e-6)
        assert (np.diff(np.asarray(res.distances), axis=1) >= -1e-6).all()


def test_sharded_nn_k_larger_than_shard():
    # k exceeds the per-device slice: merge must still be exact
    docs, ids, q = _corpus(300, 16, seed=3)
    ref = exact_nn(docs, ids, q, 120)
    res = dr.sharded_nn(docs, ids, q, 120, chunk=64)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_metric_index_sharded_path_matches_local():
    rng = np.random.default_rng(7)
    raw = rng.standard_normal((3000, 48)).astype(np.float32)
    local = MetricIndex(jnp.asarray(raw), chunk=256)
    shard = MetricIndex(jnp.asarray(raw), chunk=256, sharded=True)
    q = local.transform_queries(jnp.asarray(
        rng.standard_normal((4, 48)).astype(np.float32)))
    a = local.search(q, 30)
    b = shard.search(q, 30)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    # 1-D query convenience path
    c = shard.search(q[0], 10)
    assert c.ids.shape == (1, 10)


def test_batched_scorer_masks_and_matches_reference():
    mesh = _mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(11)
    table = jnp.asarray(rng.standard_normal((512, 64)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    scorer = dr.make_batched_scorer(mesh, k=10, table_axes=("model",),
                                    batch_axes=("data",))
    scores, idx = jax.jit(lambda a, b: scorer(a, b, n_valid=300))(q, table)
    ref = np.asarray(q @ table.T)[:, :300]
    ref_idx = np.argsort(-ref, axis=1)[:, :10]
    np.testing.assert_array_equal(np.asarray(idx), ref_idx)
    assert int(np.asarray(idx).max()) < 300


def test_device_shards_front_the_router():
    from repro.serve.router import ShardedRouter
    rng = np.random.default_rng(5)
    raw = rng.standard_normal((2000, 32)).astype(np.float32)
    index = MetricIndex(jnp.asarray(raw))
    shards = dr.make_device_shards(index.doc_emb, index.doc_ids)
    assert len(shards) >= 2
    assert len({s.device for s in shards}) == len(shards)   # distinct devices
    router = ShardedRouter(shards, deadline_s=30)
    q = np.asarray(index.transform_queries(jnp.asarray(
        rng.standard_normal((3, 32)).astype(np.float32))))
    ans, degraded = router.search(q, 15)
    assert not degraded
    ref = index.search(jnp.asarray(q), 15)
    np.testing.assert_array_equal(ans.ids, np.asarray(ref.ids))


def test_router_over_devices_constructor():
    from repro.serve.router import ShardedRouter
    rng = np.random.default_rng(9)
    raw = rng.standard_normal((500, 16)).astype(np.float32)
    index = MetricIndex(jnp.asarray(raw))
    router = ShardedRouter.over_devices(index.doc_emb, index.doc_ids,
                                        deadline_s=30)
    q = np.asarray(index.transform_queries(jnp.asarray(
        rng.standard_normal((2, 16)).astype(np.float32))))
    ans, degraded = router.search(q, 10)
    assert not degraded and ans.ids.shape == (2, 10)
    np.testing.assert_array_equal(
        ans.ids, np.asarray(index.search(jnp.asarray(q), 10).ids))
