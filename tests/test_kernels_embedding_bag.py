"""Interpret-mode validation of the EmbeddingBag kernel vs. the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _rand_case(seed, v, d, b, l, pad_frac=0.2, dtype=np.float32):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(dtype)
    idx = rng.integers(0, v, (b, l)).astype(np.int32)
    pad = rng.random((b, l)) < pad_frac
    idx = np.where(pad, -1, idx)
    w = rng.random((b, l)).astype(np.float32)
    return jnp.asarray(table), jnp.asarray(idx), jnp.asarray(w)


@pytest.mark.parametrize("v,d,b,l", [
    (100, 16, 4, 8),
    (1000, 64, 16, 26),     # dlrm-ish: 26 sparse fields
    (5000, 10, 8, 39),      # xdeepfm-ish
    (64, 200, 2, 5),        # d > lane? no: d=200 -> padded to 256
])
@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_bag_matches_ref(v, d, b, l, mode):
    table, idx, w = _rand_case(v + d + b + l, v, d, b, l)
    weights = None if mode == "max" else w
    out_k = embedding_bag(table, idx, weights, mode=mode, use_kernel=True, interpret=True)
    out_r = embedding_bag_ref(table, idx, weights, mode=mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_bag_all_padding_bag():
    table, idx, w = _rand_case(7, 50, 8, 3, 4)
    idx = idx.at[1].set(-1)
    out_k = embedding_bag(table, idx, w, mode="sum", use_kernel=True, interpret=True)
    out_r = embedding_bag_ref(table, idx, w, mode="sum")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_k[1]), 0.0, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_bag_dtype_sweep(dtype):
    table, idx, w = _rand_case(11, 128, 32, 4, 6, dtype=dtype)
    out_k = embedding_bag(table, idx, w, mode="sum", use_kernel=True, interpret=True)
    out_r = embedding_bag_ref(table, idx, w, mode="sum")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-3, atol=1e-3)


def test_bag_fallback_equals_kernel():
    table, idx, w = _rand_case(13, 300, 12, 8, 10)
    out_f = embedding_bag(table, idx, w, mode="mean", use_kernel=False)
    out_k = embedding_bag(table, idx, w, mode="mean", use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_k), rtol=1e-5, atol=1e-5)


def test_bag_property_linear_in_weights():
    """Property: bag(w1+w2) == bag(w1) + bag(w2) for sum mode."""
    table, idx, w = _rand_case(17, 80, 24, 6, 7)
    w2 = w * 0.37 + 0.1
    a = embedding_bag(table, idx, w, mode="sum", use_kernel=True, interpret=True)
    b = embedding_bag(table, idx, w2, mode="sum", use_kernel=True, interpret=True)
    ab = embedding_bag(table, idx, w + w2, mode="sum", use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a + b), np.asarray(ab), rtol=1e-4, atol=1e-4)
