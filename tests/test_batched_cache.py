"""Equivalence suite: session-batched (vmapped) cache ops must reproduce the
per-session scalar ops exactly — probe hit/r_hat/nearest_q, query results,
and every leaf of the post-insert state — across mixed hit/miss waves,
gated records, and all eviction policies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C

jax.config.update("jax_platform_name", "cpu")

DIM = 8


def _unit(rng, n, d=DIM):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _stack_states(states):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def _assert_states_equal(ref: C.CacheState, got: C.CacheState):
    for name, a, b in zip(C.CacheState._fields, ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"leaf {name} diverged")


@pytest.mark.parametrize("eviction", ["none", "lru", "ball"])
def test_batched_ops_equal_scalar_loop(eviction):
    """Five waves of probe -> masked insert -> query over 4 sessions, with
    per-session do/record masks, against the scalar ops run per session."""
    cfg = C.CacheConfig(capacity=32, dim=DIM, max_queries=4, eviction=eviction)
    S, KC, K = 4, 10, 5
    rng = np.random.default_rng(7)
    scalar = [C.init_cache(cfg) for _ in range(S)]
    batched = C.init_batched_cache(cfg, S)

    for wave in range(5):
        psi = jnp.asarray(_unit(rng, S))
        emb = jnp.asarray(_unit(rng, S * KC).reshape(S, KC, DIM))
        ids = jnp.asarray(rng.integers(0, 60, (S, KC)).astype(np.int32))
        radius = jnp.asarray(rng.uniform(0.4, 1.0, S).astype(np.float32))
        do = (jnp.ones((S,), bool) if wave == 0 else
              jnp.asarray(rng.integers(0, 2, S).astype(bool)))
        record = jnp.asarray(rng.integers(0, 2, S).astype(bool))

        bp = C.probe_batched(batched, psi, cfg.epsilon)
        batched, bdrop = C.insert_batched(batched, cfg, psi, radius, emb, ids,
                                          do=do, record=record)
        (bs, bd, bi, bsl), batched = C.query_batched(batched, psi, K)

        for s in range(S):
            sp = C.probe(scalar[s], psi[s], cfg.epsilon)
            np.testing.assert_array_equal(np.asarray(sp.hit), np.asarray(bp.hit[s]))
            np.testing.assert_array_equal(np.asarray(sp.r_hat), np.asarray(bp.r_hat[s]))
            np.testing.assert_array_equal(np.asarray(sp.nearest_q), np.asarray(bp.nearest_q[s]))
            if bool(do[s]):
                scalar[s], sdrop = C.insert(scalar[s], cfg, psi[s], radius[s],
                                            emb[s], ids[s], record[s])
                np.testing.assert_array_equal(np.asarray(sdrop), np.asarray(bdrop[s]))
            else:
                assert int(bdrop[s]) == 0
            (ss, sd, si, ssl), scalar[s] = C.query(scalar[s], psi[s], K)
            np.testing.assert_array_equal(np.asarray(si), np.asarray(bi[s]))
            np.testing.assert_array_equal(np.asarray(ss), np.asarray(bs[s]))
            np.testing.assert_array_equal(np.asarray(sd), np.asarray(bd[s]))
            np.testing.assert_array_equal(np.asarray(ssl), np.asarray(bsl[s]))

    _assert_states_equal(_stack_states(scalar), batched)


def test_batched_hit_sessions_state_untouched():
    """do=False sessions keep their state bitwise across an insert wave."""
    cfg = C.CacheConfig(capacity=16, dim=DIM)
    S, KC = 3, 6
    rng = np.random.default_rng(1)
    state = C.init_batched_cache(cfg, S)
    psi = jnp.asarray(_unit(rng, S))
    emb = jnp.asarray(_unit(rng, S * KC).reshape(S, KC, DIM))
    ids = jnp.asarray(np.arange(S * KC, dtype=np.int32).reshape(S, KC))
    radius = jnp.asarray(np.full(S, 0.7, np.float32))
    state, _ = C.insert_batched(state, cfg, psi, radius, emb, ids)
    before = jax.tree_util.tree_map(lambda x: np.asarray(x[1]), state)
    do = jnp.asarray([True, False, True])
    state, _ = C.insert_batched(state, cfg, psi, radius, emb, ids, do=do)
    after = jax.tree_util.tree_map(lambda x: np.asarray(x[1]), state)
    for name, a, b in zip(C.CacheState._fields, before, after):
        np.testing.assert_array_equal(a, b, err_msg=f"leaf {name} changed")
    # the do=True sessions did advance
    assert int(state.step[0]) == 2 and int(state.step[1]) == 1


def test_reset_sessions_isolates_one_session():
    cfg = C.CacheConfig(capacity=16, dim=DIM)
    S, KC = 3, 4
    rng = np.random.default_rng(2)
    cache = C.BatchedMetricCache(cfg, S)
    cache.insert(jnp.asarray(_unit(rng, S)),
                 jnp.asarray(np.full(S, 0.5, np.float32)),
                 jnp.asarray(_unit(rng, S * KC).reshape(S, KC, DIM)),
                 jnp.asarray(np.arange(S * KC, dtype=np.int32).reshape(S, KC)))
    assert np.asarray(cache.n_docs).tolist() == [KC] * S
    cache.reset([1])
    assert np.asarray(cache.n_docs).tolist() == [KC, 0, KC]
    assert np.asarray(cache.n_queries).tolist() == [1, 0, 1]
    fresh = C.init_cache(cfg)
    got1 = jax.tree_util.tree_map(lambda x: x[1], cache.state)
    _assert_states_equal(fresh, got1)


def test_gather_scatter_roundtrip_leaves_others_alone():
    cfg = C.CacheConfig(capacity=8, dim=DIM)
    rng = np.random.default_rng(3)
    cache = C.BatchedMetricCache(cfg, 4)
    psi = jnp.asarray(_unit(rng, 4))
    cache.insert(psi, jnp.asarray(np.full(4, 0.5, np.float32)),
                 jnp.asarray(_unit(rng, 4 * 3).reshape(4, 3, DIM)),
                 jnp.asarray(np.arange(12, dtype=np.int32).reshape(4, 3)))
    before = jax.tree_util.tree_map(np.asarray, cache.state)
    sub = cache.gather([0, 2])
    (scores, dists, ids, slots), sub = C.query_batched(sub, psi[jnp.asarray([0, 2])], 2)
    cache.scatter([0, 2], sub)
    after = jax.tree_util.tree_map(np.asarray, cache.state)
    # untouched sessions bitwise identical; touched sessions advanced a step
    for name, a, b in zip(C.CacheState._fields, before, after):
        np.testing.assert_array_equal(a[1], b[1], err_msg=name)
        np.testing.assert_array_equal(a[3], b[3], err_msg=name)
    assert after.step[0] == before.step[0] + 1     # step leaf
