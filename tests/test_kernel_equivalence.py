"""Kernel/reference equivalence across awkward shapes — all in interpret
mode, so CI exercises the Pallas code paths on CPU.

Covers the contract the serving hot path now rides on: the fused kNN scan
(on-chip cross-tile merge) and the session-batched cache probe must agree
with the jnp ref tier in ranking — including non-multiple feature/batch
dims, k > n_valid (the sentinel-id regression), single-doc corpora,
sentinel-padded shard slices, ring-wrapped query records, and the
composition of the kernel with ``shard_map``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.cache import (CacheConfig, MetricCache, init_batched_cache,
                              probe_batched)
from repro.core.metric_index import MetricIndex, exact_nn, scan_topk
from repro.kernels.knn.ops import autotune_knn, knn_search

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _corpus(seed, n, d, b):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(_unit(rng, (n, d))),
            jnp.arange(n, dtype=jnp.int32),
            jnp.asarray(_unit(rng, (b, d))))


def _assert_same(kernel_out, ref_out, rtol=2e-5, atol=2e-5):
    s_k, i_k = (np.asarray(x) for x in kernel_out)
    s_r, i_r = (np.asarray(x) for x in ref_out)
    np.testing.assert_allclose(s_k, s_r, rtol=rtol, atol=atol)
    np.testing.assert_array_equal(i_k, i_r)


# ------------------------------------------------------------- fused kNN
@pytest.mark.parametrize("n,d,b,k", [
    (257, 65, 3, 17),      # nothing aligned
    (1000, 769, 4, 10),    # paper geometry: STAR 768(+1)-d
    (300, 32, 1, 5),       # ragged corpus, single query
    (129, 130, 9, 33),     # B and D both off the sublane/lane grid
    (96, 16, 7, 96),       # k == n
])
def test_knn_fused_matches_ref_awkward_shapes(n, d, b, k):
    docs, ids, q = _corpus(n + d + b + k, n, d, b)
    _assert_same(knn_search(docs, ids, q, k, backend="interpret"),
                 knn_search(docs, ids, q, k, backend="ref"))


@pytest.mark.parametrize("n,k", [(5, 12), (3, 8), (1, 3)])
def test_knn_k_exceeds_n_valid_emits_sentinels(n, k):
    """Regression (sentinel-id leak): k > n_valid used to return the LAST
    REAL doc id at -inf score positions (padded-row argmax clipped by the
    doc_ids lookup).  Those positions must be (score -inf, id -1)."""
    docs, ids, q = _corpus(7, n, 33, 2)
    s, i = knn_search(docs, ids, q, k, backend="interpret")
    s, i = np.asarray(s), np.asarray(i)
    assert np.isneginf(s[:, n:]).all()
    np.testing.assert_array_equal(i[:, n:], -1)
    # the real prefix is still the exact answer
    ref = exact_nn(docs, ids, q, n)
    np.testing.assert_array_equal(i[:, :n], np.asarray(ref.ids))
    _assert_same((s, i), knn_search(docs, ids, q, k, backend="ref"))


def test_knn_two_stage_sentinels_and_merge_parity():
    """The A/B two-stage path gets the same sentinel hygiene: padded-tile
    extractions must not alias real ids, and its merge must equal the
    fused on-chip merge."""
    docs, ids, q = _corpus(11, 5, 16, 2)
    out2 = knn_search(docs, ids, q, 8, backend="interpret", two_stage=True)
    _assert_same(out2, knn_search(docs, ids, q, 8, backend="interpret"))
    docs, ids, q = _corpus(12, 300, 48, 3)
    out2 = knn_search(docs, ids, q, 20, tile_n=64, backend="interpret",
                      two_stage=True)
    _assert_same(out2, knn_search(docs, ids, q, 20, backend="ref"))


def test_scan_topk_contract_on_sentinel_padded_slice():
    """scan_topk tiers agree on a shard-style slice: real prefix + interior
    chunk alignment + sentinel (id -1) tail rows that must never surface."""
    rng = np.random.default_rng(5)
    real, pad = 96, 32
    docs = np.concatenate(
        [_unit(rng, (real, 24)), np.zeros((pad, 24), np.float32)])
    ids = np.concatenate([np.arange(real), np.full(pad, -1)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (4, 24)))
    docs, ids = jnp.asarray(docs), jnp.asarray(ids)
    ref = scan_topk(docs, ids, q, 10, chunk=32, backend="ref")
    ker = scan_topk(docs, ids, q, 10, chunk=32, backend="interpret")
    _assert_same(ker, ref)
    assert (np.asarray(ker[1]) >= 0).all()      # k <= real: no sentinel rows


def test_metric_index_kernel_tier_matches_ref_tier():
    rng = np.random.default_rng(4)
    raw = jnp.asarray(rng.standard_normal((900, 64)).astype(np.float32))
    idx_ref = MetricIndex(raw, use_kernel=False)
    idx_ker = MetricIndex(raw, use_kernel=True)
    assert idx_ref.backend == "ref" and idx_ker.backend == "interpret"
    q = idx_ref.transform_queries(
        jnp.asarray(rng.standard_normal((6, 64)).astype(np.float32)))
    r_ref, r_ker = idx_ref.search(q, 15), idx_ker.search(q, 15)
    np.testing.assert_array_equal(np.asarray(r_ref.ids),
                                  np.asarray(r_ker.ids))
    np.testing.assert_allclose(np.asarray(r_ref.scores),
                               np.asarray(r_ker.scores), rtol=1e-5, atol=1e-5)


def test_sharded_nn_runs_kernel_scan_per_shard():
    """The shard_map body and single-device search share one scan: the
    kernel tier composes with the mesh and stays bit-identical to exact."""
    from repro.dist.retrieval import sharded_nn
    rng = np.random.default_rng(9)
    docs = jnp.asarray(_unit(rng, (1000, 32)))
    ids = jnp.arange(1000, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 32)))
    ref = exact_nn(docs, ids, q, 25)
    res = sharded_nn(docs, ids, q, 25, chunk=64, backend="interpret")
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(ref.scores), rtol=1e-5, atol=1e-5)


def test_autotune_knn_bounds():
    tile, k_eff = autotune_knn(1 << 20, 768, 16, 100)
    assert tile & (tile - 1) == 0 and 128 <= tile <= 4096
    assert k_eff == 100
    tile_small, k_small = autotune_knn(5, 33, 2, 12)
    assert tile_small == 8 and k_small == 8


# ------------------------------------------------- session-batched probe
def _stacked_state(seed, s, qmax, d, n_queries):
    rng = np.random.default_rng(seed)
    cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax)
    state = init_batched_cache(cfg, s)
    state = state._replace(
        q_emb=jnp.asarray(_unit(rng, (s, qmax, d))),
        q_radius=jnp.asarray(
            rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
        n_queries=jnp.asarray(n_queries, jnp.int32))
    psi = jnp.asarray(_unit(rng, (s, d)))
    return state, psi


@pytest.mark.parametrize("qmax,d", [(8, 64), (33, 200), (64, 769)])
def test_probe_batched_kernel_matches_vmap_ref(qmax, d):
    """Empty, partial, full, and ring-wrapped (n_queries > max_queries)
    sessions in one wave: the fused launch must agree with vmap(probe)."""
    s = 6
    n_queries = [0, 1, qmax // 2, qmax, qmax + 3, 5 * qmax]
    state, psi = _stacked_state(qmax + d, s, qmax, d, n_queries)
    ref = probe_batched(state, psi, 0.04, backend="ref")
    ker = probe_batched(state, psi, 0.04, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(ref.nearest_q),
                                  np.asarray(ker.nearest_q))
    # r_hat agreement only on sessions that hold records (-inf == -inf else)
    live = np.asarray(n_queries) > 0
    np.testing.assert_allclose(np.asarray(ref.r_hat)[live],
                               np.asarray(ker.r_hat)[live],
                               rtol=1e-5, atol=1e-5)
    assert np.isneginf(np.asarray(ker.r_hat)[~live]).all()
    assert (np.asarray(ker.nearest_q)[~live] == -1).all()


def test_cache_probe_ring_wrapped_scalar_cache():
    """A real cache driven past max_queries: the ring overwrites the oldest
    record and the kernel probe must treat EVERY slot as live — exactly
    like the scalar jnp probe."""
    from repro.kernels.cache_probe.ops import cache_probe
    rng = np.random.default_rng(3)
    cfg = CacheConfig(capacity=256, dim=17, max_queries=4)
    cache = MetricCache(cfg)
    for i in range(7):                      # 7 inserts > max_queries=4
        psi = jnp.asarray(_unit(rng, (17,)))
        emb = jnp.asarray(_unit(rng, (3, 17)))
        ids = jnp.asarray(rng.integers(0, 100, 3), jnp.int32)
        cache.insert(psi, rng.uniform(0.3, 1.0), emb, ids)
    assert cache.total_queries == 7 and cache.n_queries == 4
    psi = jnp.asarray(_unit(rng, (17,)))
    ref = cache.probe(psi)                  # scalar jnp probe
    st = cache.state
    hit, r_hat, idx = cache_probe(st.q_emb, psi, st.q_radius, st.n_queries,
                                  cfg.epsilon, interpret=True)
    assert bool(hit) == bool(ref.hit)
    assert int(idx) == int(ref.nearest_q)
    np.testing.assert_allclose(float(r_hat), float(ref.r_hat),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("two_stage", [False, True])
def test_knn_sentinel_rows_never_win_over_negative_scores(two_stage):
    """Regression: zero-vector sentinel rows (id -1) score 0.0 and used to
    outrank real documents with negative scores on the two-stage path
    (prefix masking missed interior sentinels), surfacing id -1 at finite
    scores while real docs were dropped.  Both merge paths must mask by
    ids, wherever the sentinels sit."""
    rng = np.random.default_rng(13)
    q = _unit(rng, (2, 16))
    real = _unit(rng, (8, 16))
    real[:4] = -_unit(rng, (2, 16)).mean(0)     # make some scores negative
    real = real / np.linalg.norm(real, axis=1, keepdims=True)
    docs = np.concatenate([real[:4], np.zeros((8, 16), np.float32), real[4:]])
    ids = np.concatenate(
        [np.arange(4), np.full(8, -1), np.arange(4, 8)]).astype(np.int32)
    s, i = knn_search(jnp.asarray(docs), jnp.asarray(ids), jnp.asarray(q), 8,
                      tile_n=8, backend="interpret", two_stage=two_stage)
    s, i = np.asarray(s), np.asarray(i)
    assert (i >= 0).all(), f"sentinel rows leaked into top-k: {i}"
    assert np.isfinite(s).all()
    _assert_same((s, i), knn_search(jnp.asarray(docs), jnp.asarray(ids),
                                    jnp.asarray(q), 8, backend="ref"))


# ---------------------------------------------- quantized corpus (ISSUE 4)
# Rank-equality contract of the quantized scan:
#   * at a FIXED dtype, every tier (ref / interpret) returns identical ids —
#     the tiers share one dequantization rule (payload -> f32, score-side
#     scale), so quantization error cancels across tiers;
#   * vs the fp32 corpus, rank equality is tolerance-bound: top-k *score*
#     agreement within the dtype's quantization error (bf16 ~4e-3, int8
#     ~2e-2 on unit vectors) and set-overlap floors enforced in
#     benchmarks/kernel_bench.py (RANK_OVERLAP_FLOOR: bf16 0.95, int8 0.90).
SCORE_TOL = {"fp32": 0.0, "bf16": 6e-3, "int8": 2e-2}


@pytest.mark.parametrize("dt", quant.DTYPES)
def test_quantized_tiers_agree_on_near_tied_scores(dt):
    """Adversarial near-ties: clusters of almost-identical documents whose
    fp32 scores differ by less than the quantization step.  Order within a
    cluster may legally differ vs fp32 — but the tiers must agree with
    EACH OTHER exactly, and the top-k score multiset must match fp32 to the
    dtype tolerance."""
    rng = np.random.default_rng(21)
    base = _unit(rng, (8, 64))
    # 8 clusters x 8 members, members perturbed by ~1e-4 (below int8 step)
    docs = np.repeat(base, 8, axis=0) + 1e-4 * _unit(rng, (64, 64))
    docs = docs / np.linalg.norm(docs, axis=1, keepdims=True)
    ids = jnp.arange(64, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 64)))
    qc = quant.quantize(jnp.asarray(docs), dt)

    ref = knn_search(qc.data, ids, q, 16, backend="ref", scale=qc.scale)
    ker = knn_search(qc.data, ids, q, 16, backend="interpret", scale=qc.scale)
    _assert_same(ker, ref)
    fp = knn_search(jnp.asarray(docs), ids, q, 16, backend="ref")
    np.testing.assert_allclose(np.asarray(ref[0]), np.asarray(fp[0]),
                               atol=SCORE_TOL[dt] + 1e-6, rtol=0)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_sentinel_rows_never_win(dt):
    """Interior sentinel-padded rows (id -1, zero payload) among real docs
    with negative scores: the id-driven masking must hold at every dtype —
    a zero int8 payload scores 0.0, which would outrank the real docs."""
    rng = np.random.default_rng(22)
    q = _unit(rng, (2, 16))
    real = _unit(rng, (8, 16))
    real[:4] = -_unit(rng, (2, 16)).mean(0)
    real = real / np.linalg.norm(real, axis=1, keepdims=True)
    docs = np.concatenate([real[:4], np.zeros((8, 16), np.float32), real[4:]])
    ids = np.concatenate(
        [np.arange(4), np.full(8, -1), np.arange(4, 8)]).astype(np.int32)
    qc = quant.quantize(jnp.asarray(docs), dt)
    for backend in ("ref", "interpret"):
        s, i = knn_search(qc.data, jnp.asarray(ids), jnp.asarray(q), 8,
                          tile_n=8, backend=backend, scale=qc.scale)
        s, i = np.asarray(s), np.asarray(i)
        assert (i >= 0).all(), f"{dt}/{backend}: sentinel leaked: {i}"
        assert np.isfinite(s).all()


@pytest.mark.parametrize("dt", ["bf16", "int8"])
@pytest.mark.parametrize("n,k", [(5, 12), (1, 3)])
def test_quantized_k_exceeds_n_valid_emits_sentinels(dt, n, k):
    """k > n_valid at quantized dtypes: -inf positions must carry id -1 in
    both tiers (the sentinel-id hygiene of the fp32 path, unchanged)."""
    docs, ids, q = _corpus(23 + n, n, 33, 2)
    qc = quant.quantize(docs, dt)
    for backend in ("ref", "interpret"):
        s, i = knn_search(qc.data, ids, q, k, backend=backend,
                          scale=qc.scale)
        s, i = np.asarray(s), np.asarray(i)
        assert np.isneginf(s[:, n:]).all(), f"{dt}/{backend}"
        np.testing.assert_array_equal(i[:, n:], -1)
        assert (i[:, :n] >= 0).all()


@pytest.mark.parametrize("dt", quant.DTYPES)
def test_quantized_scan_topk_tiers_agree_on_shard_slice(dt):
    """The scan contract on a sentinel-padded shard-style slice, per dtype:
    ref (chunked streaming dequant) vs interpret (VMEM tile dequant)."""
    rng = np.random.default_rng(24)
    real, pad = 96, 32
    docs = np.concatenate(
        [_unit(rng, (real, 24)), np.zeros((pad, 24), np.float32)])
    ids = np.concatenate([np.arange(real), np.full(pad, -1)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (4, 24)))
    qc = quant.quantize(jnp.asarray(docs), dt)
    ref = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="ref", scale=qc.scale)
    ker = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="interpret", scale=qc.scale)
    _assert_same(ker, ref)
    assert (np.asarray(ker[1]) >= 0).all()


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_ring_wrapped_cache_probe_matches_ref(dt):
    """A quantized-storage cache driven past max_queries (ring wrap): the
    kernel probe must agree with the jnp ref probe on the SAME quantized
    records — storage error is shared, tier disagreement is a bug."""
    from repro.kernels.cache_probe.ops import cache_probe
    rng = np.random.default_rng(25)
    cfg = CacheConfig(capacity=256, dim=17, max_queries=4, store_dtype=dt)
    cache = MetricCache(cfg)
    for _ in range(7):                      # 7 inserts > max_queries=4
        psi = jnp.asarray(_unit(rng, (17,)))
        emb = jnp.asarray(_unit(rng, (3, 17)))
        ids = jnp.asarray(rng.integers(0, 100, 3), jnp.int32)
        cache.insert(psi, rng.uniform(0.3, 1.0), emb, ids)
    assert cache.total_queries == 7 and cache.n_queries == 4
    psi = jnp.asarray(_unit(rng, (17,)))
    ref = cache.probe(psi, use_kernel=False)
    st = cache.state
    hit, r_hat, idx = cache_probe(st.q_emb, psi, st.q_radius, st.n_queries,
                                  cfg.epsilon, q_scale=st.q_scale,
                                  interpret=True)
    assert bool(hit) == bool(ref.hit)
    assert int(idx) == int(ref.nearest_q)
    np.testing.assert_allclose(float(r_hat), float(ref.r_hat),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_batched_probe_kernel_matches_vmap_ref(dt):
    """Ring-wrapped quantized record storage through the BATCHED probe:
    one fused launch over the stacked state vs vmap(probe), per dtype."""
    s, qmax, d = 6, 8, 64
    rng = np.random.default_rng(26)
    cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax, store_dtype=dt)
    state = init_batched_cache(cfg, s)
    rec = quant.quantize(jnp.asarray(_unit(rng, (s, qmax, d))), dt)
    state = state._replace(
        q_emb=rec.data,
        q_scale=(state.q_scale if rec.scale is None else rec.scale),
        q_radius=jnp.asarray(
            rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
        n_queries=jnp.asarray([0, 1, qmax // 2, qmax, qmax + 3, 5 * qmax],
                              jnp.int32))
    psi = jnp.asarray(_unit(rng, (s, d)))
    ref = probe_batched(state, psi, 0.04, backend="ref")
    ker = probe_batched(state, psi, 0.04, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(ref.nearest_q),
                                  np.asarray(ker.nearest_q))
    live = np.asarray(state.n_queries) > 0
    np.testing.assert_allclose(np.asarray(ref.r_hat)[live],
                               np.asarray(ker.r_hat)[live],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_sharded_nn_matches_single_device(dt):
    """The quantized scan composes with shard_map: per-shard scales ride
    the corpus row sharding and the merged top-k equals the single-device
    quantized answer."""
    from repro.dist.retrieval import sharded_nn
    rng = np.random.default_rng(27)
    docs = jnp.asarray(_unit(rng, (1000, 32)))
    ids = jnp.arange(1000, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 32)))
    qc = quant.quantize(docs, dt)
    single = knn_search(qc.data, ids, q, 25, backend="ref", scale=qc.scale)
    res = sharded_nn(qc.data, ids, q, 25, chunk=64, backend="interpret",
                     scale=qc.scale)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(single[1]))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(single[0]), rtol=1e-5, atol=1e-5)


def test_autotune_widens_tiles_for_narrow_dtypes():
    """The VMEM budget is element-width aware: at serving shapes the tile
    roughly doubles fp32 -> bf16 and again bf16 -> int8."""
    t32, _ = autotune_knn(1 << 20, 768, 16, 100, 4)
    t16, _ = autotune_knn(1 << 20, 768, 16, 100, 2)
    t8, _ = autotune_knn(1 << 20, 768, 16, 100, 1)
    assert t32 < t16 <= t8
    assert t16 >= 2 * t32
