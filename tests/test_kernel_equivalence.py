"""Kernel/reference equivalence across awkward shapes — all in interpret
mode, so CI exercises the Pallas code paths on CPU.

Covers the contract the serving hot path now rides on: the double-buffered
fused kNN scan (on-chip cross-tile merge), the native int8-MXU-dot tier,
the session-batched cache probe, and the fused wave kernels backing
``query_batched`` / ``insert_batched`` / ``insert_query_batched`` must
agree with the jnp ref tier — including non-multiple feature/batch dims,
k > n_valid (the sentinel-id regression), single-doc corpora,
sentinel-padded shard slices, ring-wrapped query records, evict-while-
append waves, per-session do/record gating, and the composition of the
kernel with ``shard_map``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as C
from repro.core import quant
from repro.core.cache import (CacheConfig, MetricCache, init_batched_cache,
                              probe_batched)
from repro.core.metric_index import MetricIndex, exact_nn, scan_topk
from repro.kernels.knn.ops import autotune_knn, knn_search

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.kernels  # fast CI kernel gate: pytest -m kernels


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _corpus(seed, n, d, b):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(_unit(rng, (n, d))),
            jnp.arange(n, dtype=jnp.int32),
            jnp.asarray(_unit(rng, (b, d))))


def _assert_same(kernel_out, ref_out, rtol=2e-5, atol=2e-5):
    s_k, i_k = (np.asarray(x) for x in kernel_out)
    s_r, i_r = (np.asarray(x) for x in ref_out)
    np.testing.assert_allclose(s_k, s_r, rtol=rtol, atol=atol)
    np.testing.assert_array_equal(i_k, i_r)


# ------------------------------------------------------------- fused kNN
@pytest.mark.parametrize("n,d,b,k", [
    (257, 65, 3, 17),      # nothing aligned
    (1000, 769, 4, 10),    # paper geometry: STAR 768(+1)-d
    (300, 32, 1, 5),       # ragged corpus, single query
    (129, 130, 9, 33),     # B and D both off the sublane/lane grid
    (96, 16, 7, 96),       # k == n
])
def test_knn_fused_matches_ref_awkward_shapes(n, d, b, k):
    docs, ids, q = _corpus(n + d + b + k, n, d, b)
    _assert_same(knn_search(docs, ids, q, k, backend="interpret"),
                 knn_search(docs, ids, q, k, backend="ref"))


@pytest.mark.parametrize("n,k", [(5, 12), (3, 8), (1, 3)])
def test_knn_k_exceeds_n_valid_emits_sentinels(n, k):
    """Regression (sentinel-id leak): k > n_valid used to return the LAST
    REAL doc id at -inf score positions (padded-row argmax clipped by the
    doc_ids lookup).  Those positions must be (score -inf, id -1)."""
    docs, ids, q = _corpus(7, n, 33, 2)
    s, i = knn_search(docs, ids, q, k, backend="interpret")
    s, i = np.asarray(s), np.asarray(i)
    assert np.isneginf(s[:, n:]).all()
    np.testing.assert_array_equal(i[:, n:], -1)
    # the real prefix is still the exact answer
    ref = exact_nn(docs, ids, q, n)
    np.testing.assert_array_equal(i[:, :n], np.asarray(ref.ids))
    _assert_same((s, i), knn_search(docs, ids, q, k, backend="ref"))


def test_knn_two_stage_sentinels_and_merge_parity():
    """The A/B two-stage path gets the same sentinel hygiene: padded-tile
    extractions must not alias real ids, and its merge must equal the
    fused on-chip merge."""
    docs, ids, q = _corpus(11, 5, 16, 2)
    out2 = knn_search(docs, ids, q, 8, backend="interpret", two_stage=True)
    _assert_same(out2, knn_search(docs, ids, q, 8, backend="interpret"))
    docs, ids, q = _corpus(12, 300, 48, 3)
    out2 = knn_search(docs, ids, q, 20, tile_n=64, backend="interpret",
                      two_stage=True)
    _assert_same(out2, knn_search(docs, ids, q, 20, backend="ref"))


def test_scan_topk_contract_on_sentinel_padded_slice():
    """scan_topk tiers agree on a shard-style slice: real prefix + interior
    chunk alignment + sentinel (id -1) tail rows that must never surface."""
    rng = np.random.default_rng(5)
    real, pad = 96, 32
    docs = np.concatenate(
        [_unit(rng, (real, 24)), np.zeros((pad, 24), np.float32)])
    ids = np.concatenate([np.arange(real), np.full(pad, -1)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (4, 24)))
    docs, ids = jnp.asarray(docs), jnp.asarray(ids)
    ref = scan_topk(docs, ids, q, 10, chunk=32, backend="ref")
    ker = scan_topk(docs, ids, q, 10, chunk=32, backend="interpret")
    _assert_same(ker, ref)
    assert (np.asarray(ker[1]) >= 0).all()      # k <= real: no sentinel rows


def test_metric_index_kernel_tier_matches_ref_tier():
    rng = np.random.default_rng(4)
    raw = jnp.asarray(rng.standard_normal((900, 64)).astype(np.float32))
    idx_ref = MetricIndex(raw, use_kernel=False)
    idx_ker = MetricIndex(raw, use_kernel=True)
    assert idx_ref.backend == "ref" and idx_ker.backend == "interpret"
    q = idx_ref.transform_queries(
        jnp.asarray(rng.standard_normal((6, 64)).astype(np.float32)))
    r_ref, r_ker = idx_ref.search(q, 15), idx_ker.search(q, 15)
    np.testing.assert_array_equal(np.asarray(r_ref.ids),
                                  np.asarray(r_ker.ids))
    np.testing.assert_allclose(np.asarray(r_ref.scores),
                               np.asarray(r_ker.scores), rtol=1e-5, atol=1e-5)


def test_sharded_nn_runs_kernel_scan_per_shard():
    """The shard_map body and single-device search share one scan: the
    kernel tier composes with the mesh and stays bit-identical to exact."""
    from repro.dist.retrieval import sharded_nn
    rng = np.random.default_rng(9)
    docs = jnp.asarray(_unit(rng, (1000, 32)))
    ids = jnp.arange(1000, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 32)))
    ref = exact_nn(docs, ids, q, 25)
    res = sharded_nn(docs, ids, q, 25, chunk=64, backend="interpret")
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(ref.scores), rtol=1e-5, atol=1e-5)


def test_autotune_knn_bounds():
    tile, k_eff = autotune_knn(1 << 20, 768, 16, 100)
    assert tile & (tile - 1) == 0 and 128 <= tile <= 4096
    assert k_eff == 100
    tile_small, k_small = autotune_knn(5, 33, 2, 12)
    assert tile_small == 8 and k_small == 8


# ------------------------------------------------- session-batched probe
def _stacked_state(seed, s, qmax, d, n_queries):
    rng = np.random.default_rng(seed)
    cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax)
    state = init_batched_cache(cfg, s)
    # deliberately replace the ring leaves with LOGICAL-extent arrays (not
    # the pre-padded physical ones): the probe wrappers must still accept
    # direct-call states of arbitrary shape, padding on the fly
    state = state._replace(
        q_emb=jnp.asarray(_unit(rng, (s, qmax, d))),
        q_radius=jnp.asarray(
            rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
        q_scale=jnp.ones((s, qmax), jnp.float32),
        n_queries=jnp.asarray(n_queries, jnp.int32))
    psi = jnp.asarray(_unit(rng, (s, d)))
    return state, psi


@pytest.mark.parametrize("qmax,d", [(8, 64), (33, 200), (64, 769)])
def test_probe_batched_kernel_matches_vmap_ref(qmax, d):
    """Empty, partial, full, and ring-wrapped (n_queries > max_queries)
    sessions in one wave: the fused launch must agree with vmap(probe)."""
    s = 6
    n_queries = [0, 1, qmax // 2, qmax, qmax + 3, 5 * qmax]
    state, psi = _stacked_state(qmax + d, s, qmax, d, n_queries)
    ref = probe_batched(state, psi, 0.04, backend="ref")
    ker = probe_batched(state, psi, 0.04, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(ref.nearest_q),
                                  np.asarray(ker.nearest_q))
    # r_hat agreement only on sessions that hold records (-inf == -inf else)
    live = np.asarray(n_queries) > 0
    np.testing.assert_allclose(np.asarray(ref.r_hat)[live],
                               np.asarray(ker.r_hat)[live],
                               rtol=1e-5, atol=1e-5)
    assert np.isneginf(np.asarray(ker.r_hat)[~live]).all()
    assert (np.asarray(ker.nearest_q)[~live] == -1).all()


def test_cache_probe_ring_wrapped_scalar_cache():
    """A real cache driven past max_queries: the ring overwrites the oldest
    record and the kernel probe must treat EVERY slot as live — exactly
    like the scalar jnp probe."""
    from repro.kernels.cache_probe.ops import cache_probe
    rng = np.random.default_rng(3)
    cfg = CacheConfig(capacity=256, dim=17, max_queries=4)
    cache = MetricCache(cfg)
    for i in range(7):                      # 7 inserts > max_queries=4
        psi = jnp.asarray(_unit(rng, (17,)))
        emb = jnp.asarray(_unit(rng, (3, 17)))
        ids = jnp.asarray(rng.integers(0, 100, 3), jnp.int32)
        cache.insert(psi, rng.uniform(0.3, 1.0), emb, ids)
    assert cache.total_queries == 7 and cache.n_queries == 4
    psi = jnp.asarray(_unit(rng, (17,)))
    ref = cache.probe(psi)                  # scalar jnp probe
    st = cache.state
    hit, r_hat, idx = cache_probe(st.q_emb, psi, st.q_radius, st.n_queries,
                                  cfg.epsilon, interpret=True)
    assert bool(hit) == bool(ref.hit)
    assert int(idx) == int(ref.nearest_q)
    np.testing.assert_allclose(float(r_hat), float(ref.r_hat),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("two_stage", [False, True])
def test_knn_sentinel_rows_never_win_over_negative_scores(two_stage):
    """Regression: zero-vector sentinel rows (id -1) score 0.0 and used to
    outrank real documents with negative scores on the two-stage path
    (prefix masking missed interior sentinels), surfacing id -1 at finite
    scores while real docs were dropped.  Both merge paths must mask by
    ids, wherever the sentinels sit."""
    rng = np.random.default_rng(13)
    q = _unit(rng, (2, 16))
    real = _unit(rng, (8, 16))
    real[:4] = -_unit(rng, (2, 16)).mean(0)     # make some scores negative
    real = real / np.linalg.norm(real, axis=1, keepdims=True)
    docs = np.concatenate([real[:4], np.zeros((8, 16), np.float32), real[4:]])
    ids = np.concatenate(
        [np.arange(4), np.full(8, -1), np.arange(4, 8)]).astype(np.int32)
    s, i = knn_search(jnp.asarray(docs), jnp.asarray(ids), jnp.asarray(q), 8,
                      tile_n=8, backend="interpret", two_stage=two_stage)
    s, i = np.asarray(s), np.asarray(i)
    assert (i >= 0).all(), f"sentinel rows leaked into top-k: {i}"
    assert np.isfinite(s).all()
    _assert_same((s, i), knn_search(jnp.asarray(docs), jnp.asarray(ids),
                                    jnp.asarray(q), 8, backend="ref"))


# ---------------------------------------------- quantized corpus (ISSUE 4)
# Rank-equality contract of the quantized scan:
#   * at a FIXED dtype, every tier (ref / interpret) returns identical ids —
#     the tiers share one dequantization rule (payload -> f32, score-side
#     scale), so quantization error cancels across tiers;
#   * vs the fp32 corpus, rank equality is tolerance-bound: top-k *score*
#     agreement within the dtype's quantization error (bf16 ~4e-3, int8
#     ~2e-2 on unit vectors) and set-overlap floors enforced in
#     benchmarks/kernel_bench.py (RANK_OVERLAP_FLOOR: bf16 0.95, int8 0.90).
SCORE_TOL = {"fp32": 0.0, "bf16": 6e-3, "int8": 2e-2}


@pytest.mark.parametrize("dt", quant.DTYPES)
def test_quantized_tiers_agree_on_near_tied_scores(dt):
    """Adversarial near-ties: clusters of almost-identical documents whose
    fp32 scores differ by less than the quantization step.  Order within a
    cluster may legally differ vs fp32 — but the tiers must agree with
    EACH OTHER exactly, and the top-k score multiset must match fp32 to the
    dtype tolerance."""
    rng = np.random.default_rng(21)
    base = _unit(rng, (8, 64))
    # 8 clusters x 8 members, members perturbed by ~1e-4 (below int8 step)
    docs = np.repeat(base, 8, axis=0) + 1e-4 * _unit(rng, (64, 64))
    docs = docs / np.linalg.norm(docs, axis=1, keepdims=True)
    ids = jnp.arange(64, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 64)))
    qc = quant.quantize(jnp.asarray(docs), dt)

    # pin the dequantize-first rule: this test documents ITS score
    # tolerance (the int8-MXU tier has its own tests + overlap gate)
    ref = knn_search(qc.data, ids, q, 16, backend="ref", scale=qc.scale,
                     int8_dot=False)
    ker = knn_search(qc.data, ids, q, 16, backend="interpret",
                     scale=qc.scale, int8_dot=False)
    _assert_same(ker, ref)
    fp = knn_search(jnp.asarray(docs), ids, q, 16, backend="ref")
    np.testing.assert_allclose(np.asarray(ref[0]), np.asarray(fp[0]),
                               atol=SCORE_TOL[dt] + 1e-6, rtol=0)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_sentinel_rows_never_win(dt):
    """Interior sentinel-padded rows (id -1, zero payload) among real docs
    with negative scores: the id-driven masking must hold at every dtype —
    a zero int8 payload scores 0.0, which would outrank the real docs."""
    rng = np.random.default_rng(22)
    q = _unit(rng, (2, 16))
    real = _unit(rng, (8, 16))
    real[:4] = -_unit(rng, (2, 16)).mean(0)
    real = real / np.linalg.norm(real, axis=1, keepdims=True)
    docs = np.concatenate([real[:4], np.zeros((8, 16), np.float32), real[4:]])
    ids = np.concatenate(
        [np.arange(4), np.full(8, -1), np.arange(4, 8)]).astype(np.int32)
    qc = quant.quantize(jnp.asarray(docs), dt)
    for backend in ("ref", "interpret"):
        s, i = knn_search(qc.data, jnp.asarray(ids), jnp.asarray(q), 8,
                          tile_n=8, backend=backend, scale=qc.scale)
        s, i = np.asarray(s), np.asarray(i)
        assert (i >= 0).all(), f"{dt}/{backend}: sentinel leaked: {i}"
        assert np.isfinite(s).all()


@pytest.mark.parametrize("dt", ["bf16", "int8"])
@pytest.mark.parametrize("n,k", [(5, 12), (1, 3)])
def test_quantized_k_exceeds_n_valid_emits_sentinels(dt, n, k):
    """k > n_valid at quantized dtypes: -inf positions must carry id -1 in
    both tiers (the sentinel-id hygiene of the fp32 path, unchanged)."""
    docs, ids, q = _corpus(23 + n, n, 33, 2)
    qc = quant.quantize(docs, dt)
    for backend in ("ref", "interpret"):
        s, i = knn_search(qc.data, ids, q, k, backend=backend,
                          scale=qc.scale)
        s, i = np.asarray(s), np.asarray(i)
        assert np.isneginf(s[:, n:]).all(), f"{dt}/{backend}"
        np.testing.assert_array_equal(i[:, n:], -1)
        assert (i[:, :n] >= 0).all()


@pytest.mark.parametrize("dt", quant.DTYPES)
def test_quantized_scan_topk_tiers_agree_on_shard_slice(dt):
    """The scan contract on a sentinel-padded shard-style slice, per dtype:
    ref (chunked streaming dequant) vs interpret (VMEM tile dequant)."""
    rng = np.random.default_rng(24)
    real, pad = 96, 32
    docs = np.concatenate(
        [_unit(rng, (real, 24)), np.zeros((pad, 24), np.float32)])
    ids = np.concatenate([np.arange(real), np.full(pad, -1)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (4, 24)))
    qc = quant.quantize(jnp.asarray(docs), dt)
    ref = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="ref", scale=qc.scale)
    ker = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="interpret", scale=qc.scale)
    _assert_same(ker, ref)
    assert (np.asarray(ker[1]) >= 0).all()


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_ring_wrapped_cache_probe_matches_ref(dt):
    """A quantized-storage cache driven past max_queries (ring wrap): the
    kernel probe must agree with the jnp ref probe on the SAME quantized
    records — storage error is shared, tier disagreement is a bug."""
    from repro.kernels.cache_probe.ops import cache_probe
    rng = np.random.default_rng(25)
    cfg = CacheConfig(capacity=256, dim=17, max_queries=4, store_dtype=dt)
    cache = MetricCache(cfg)
    for _ in range(7):                      # 7 inserts > max_queries=4
        psi = jnp.asarray(_unit(rng, (17,)))
        emb = jnp.asarray(_unit(rng, (3, 17)))
        ids = jnp.asarray(rng.integers(0, 100, 3), jnp.int32)
        cache.insert(psi, rng.uniform(0.3, 1.0), emb, ids)
    assert cache.total_queries == 7 and cache.n_queries == 4
    psi = jnp.asarray(_unit(rng, (17,)))
    ref = cache.probe(psi, use_kernel=False)
    st = cache.state
    hit, r_hat, idx = cache_probe(st.q_emb, psi, st.q_radius, st.n_queries,
                                  cfg.epsilon, q_scale=st.q_scale,
                                  interpret=True)
    assert bool(hit) == bool(ref.hit)
    assert int(idx) == int(ref.nearest_q)
    np.testing.assert_allclose(float(r_hat), float(ref.r_hat),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_batched_probe_kernel_matches_vmap_ref(dt):
    """Ring-wrapped quantized record storage through the BATCHED probe:
    one fused launch over the stacked state vs vmap(probe), per dtype."""
    s, qmax, d = 6, 8, 64
    rng = np.random.default_rng(26)
    cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax, store_dtype=dt)
    state = init_batched_cache(cfg, s)
    rec = quant.quantize(jnp.asarray(_unit(rng, (s, qmax, d))), dt)
    state = state._replace(
        q_emb=rec.data,
        q_scale=(state.q_scale if rec.scale is None else rec.scale),
        q_radius=jnp.asarray(
            rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
        n_queries=jnp.asarray([0, 1, qmax // 2, qmax, qmax + 3, 5 * qmax],
                              jnp.int32))
    psi = jnp.asarray(_unit(rng, (s, d)))
    ref = probe_batched(state, psi, 0.04, backend="ref")
    ker = probe_batched(state, psi, 0.04, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(ref.nearest_q),
                                  np.asarray(ker.nearest_q))
    live = np.asarray(state.n_queries) > 0
    np.testing.assert_allclose(np.asarray(ref.r_hat)[live],
                               np.asarray(ker.r_hat)[live],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_quantized_sharded_nn_matches_single_device(dt):
    """The quantized scan composes with shard_map: per-shard scales ride
    the corpus row sharding and the merged top-k equals the single-device
    quantized answer."""
    from repro.dist.retrieval import sharded_nn
    rng = np.random.default_rng(27)
    docs = jnp.asarray(_unit(rng, (1000, 32)))
    ids = jnp.arange(1000, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 32)))
    qc = quant.quantize(docs, dt)
    single = knn_search(qc.data, ids, q, 25, backend="ref", scale=qc.scale)
    res = sharded_nn(qc.data, ids, q, 25, chunk=64, backend="interpret",
                     scale=qc.scale)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(single[1]))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(single[0]), rtol=1e-5, atol=1e-5)


def test_autotune_widens_tiles_for_narrow_dtypes():
    """The VMEM budget is element-width aware: at serving shapes the tile
    roughly doubles fp32 -> bf16 and again bf16 -> int8."""
    t32, _ = autotune_knn(1 << 20, 768, 16, 100, 4)
    t16, _ = autotune_knn(1 << 20, 768, 16, 100, 2)
    t8, _ = autotune_knn(1 << 20, 768, 16, 100, 1)
    assert t32 < t16 <= t8
    assert t16 >= 2 * t32


def test_autotune_budgets_two_resident_tiles_64k():
    """Regression pin (ISSUE 5): the pipelined kernel keeps TWO corpus
    tiles resident (prefetch + in-use), so the chosen tiles at the 64K x
    768 serving geometry are exactly half the single-buffered era's — and
    the double-buffered footprint of the NEXT power of two must overflow
    the ~6 MB budget (else the tuner left bandwidth on the table)."""
    budget = 6 * 2 ** 20
    expect = {4: 512, 2: 1024, 1: 2048}
    for itemsize, tile in expect.items():
        got, k_eff = autotune_knn(65536, 768, 16, 100, itemsize)
        assert got == tile, f"itemsize {itemsize}: tile {got} != {tile}"
        assert k_eff == 100
        # 2x tile + id/scale columns + query block + carry + merge pool
        def footprint(t):
            return (2 * t * (itemsize * 768 + 8)
                    + 4 * 16 * 768 + 8 * 16 * 100 + 12 * 16 * (100 + t))
        assert footprint(tile) <= budget < footprint(2 * tile)


# ------------------------------------------------ int8 MXU dots (ISSUE 5)
def test_int8_dot_tiers_agree_and_hold_overlap_floor():
    """The native int8 x int8 -> int32 scoring rule: ref and kernel tiers
    must agree EXACTLY with each other (they share the rule and the
    wrapper-quantized query payload), and the ranking vs the fp32 corpus
    must hold the established int8 floor (>= 0.90 top-k overlap)."""
    rng = np.random.default_rng(31)
    docs = jnp.asarray(_unit(rng, (2048, 128)))
    ids = jnp.arange(2048, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (4, 128)))
    qc = quant.quantize(docs, "int8")
    ref = knn_search(qc.data, ids, q, 10, backend="ref", scale=qc.scale,
                     int8_dot=True)
    ker = knn_search(qc.data, ids, q, 10, backend="interpret",
                     scale=qc.scale, int8_dot=True)
    _assert_same(ker, ref)
    fp = knn_search(docs, ids, q, 10, backend="ref")
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 10
        for a, b in zip(np.asarray(ref[1]), np.asarray(fp[1]))])
    assert overlap >= 0.90, f"int8-dot overlap vs fp32 = {overlap:.3f}"
    # the two-stage A/B baseline shares the rule
    two = knn_search(qc.data, ids, q, 10, tile_n=256, backend="interpret",
                     two_stage=True, scale=qc.scale, int8_dot=True)
    _assert_same(two, ref)


def test_int8_dot_sentinel_hygiene_and_k_exceeds_n_valid():
    """Interior sentinels and k > n_valid under the int8-MXU rule: an
    all-zero int8 payload accumulates to 0 — the id-driven masking must
    still keep it out, and -inf positions must carry id -1."""
    rng = np.random.default_rng(32)
    real = _unit(rng, (8, 16))
    real[:4] = -np.abs(real[:4])
    real = real / np.linalg.norm(real, axis=1, keepdims=True)
    docs = np.concatenate([real[:4], np.zeros((8, 16), np.float32), real[4:]])
    ids = np.concatenate(
        [np.arange(4), np.full(8, -1), np.arange(4, 8)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (2, 16)))
    qc = quant.quantize(jnp.asarray(docs), "int8")
    for backend in ("ref", "interpret"):
        s, i = knn_search(qc.data, jnp.asarray(ids), q, 8, tile_n=8,
                          backend=backend, scale=qc.scale, int8_dot=True)
        assert (np.asarray(i) >= 0).all()
        s, i = knn_search(qc.data[:4], jnp.asarray(ids[:4]), q, 9,
                          backend=backend, scale=qc.scale[:4], int8_dot=True)
        s, i = np.asarray(s), np.asarray(i)
        assert np.isneginf(s[:, 4:]).all()
        np.testing.assert_array_equal(i[:, 4:], -1)


def test_int8_dot_ignored_on_wide_corpora():
    """int8_dot on an fp32/bf16 payload is a no-op, never an error — the
    results are bitwise the dequantize-first answer."""
    docs, ids, q = _corpus(33, 200, 32, 3)
    a = knn_search(docs, ids, q, 7, backend="interpret", int8_dot=True)
    b = knn_search(docs, ids, q, 7, backend="interpret", int8_dot=False)
    _assert_same(a, b, rtol=0, atol=0)


def test_int8_dot_streaming_ref_tier_matches_kernel():
    """``scan_topk``'s ref tier (the chunked streaming scan) implements the
    int8-dot rule too — same query quantization, same score association —
    so tier parity holds through the one-scan contract, including on a
    sentinel-padded shard slice."""
    rng = np.random.default_rng(34)
    docs = np.concatenate(
        [_unit(rng, (96, 24)), np.zeros((32, 24), np.float32)])
    ids = np.concatenate([np.arange(96), np.full(32, -1)]).astype(np.int32)
    q = jnp.asarray(_unit(rng, (4, 24)))
    qc = quant.quantize(jnp.asarray(docs), "int8")
    ref = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="ref", scale=qc.scale, int8_dot=True)
    ker = scan_topk(qc.data, jnp.asarray(ids), q, 10, chunk=32,
                    backend="interpret", scale=qc.scale, int8_dot=True)
    _assert_same(ker, ref)


def test_int8_dot_sharded_nn_matches_single_device():
    """int8-dot composes with shard_map: queries quantize identically per
    shard, so the merged top-k equals the single-device int8-dot answer."""
    from repro.dist.retrieval import sharded_nn
    rng = np.random.default_rng(35)
    docs = jnp.asarray(_unit(rng, (1000, 32)))
    ids = jnp.arange(1000, dtype=jnp.int32)
    q = jnp.asarray(_unit(rng, (3, 32)))
    qc = quant.quantize(docs, "int8")
    single = knn_search(qc.data, ids, q, 25, backend="ref", scale=qc.scale,
                        int8_dot=True)
    res = sharded_nn(qc.data, ids, q, 25, chunk=64, backend="interpret",
                     scale=qc.scale, int8_dot=True)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(single[1]))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(single[0]), rtol=1e-5, atol=1e-5)


# ------------------------------------------- fused wave kernels (ISSUE 5)
def _assert_states_equal(ref, got, msg=""):
    for name, a, b in zip(C.CacheState._fields, ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg} leaf {name}")


def _assert_query_equal(out_r, out_k):
    np.testing.assert_allclose(np.asarray(out_r[0]), np.asarray(out_k[0]),
                               rtol=1e-5, atol=1e-5)          # scores
    np.testing.assert_allclose(np.asarray(out_r[1]), np.asarray(out_k[1]),
                               rtol=1e-5, atol=1e-5)          # distances
    np.testing.assert_array_equal(np.asarray(out_r[2]),
                                  np.asarray(out_k[2]))       # ids
    np.testing.assert_array_equal(np.asarray(out_r[3]),
                                  np.asarray(out_k[3]))       # slots


def _filled_states(rng, cfg, s, fills):
    """Two identical stacked states with per-session fill levels."""
    state = C.init_batched_cache(cfg, s)
    for sess, n in enumerate(fills):
        if n == 0:
            continue
        one = jax.tree_util.tree_map(lambda x: x[sess], state)
        one, _ = C.insert(one, cfg, jnp.asarray(_unit(rng, (cfg.dim,))),
                          jnp.asarray(0.8, jnp.float32),
                          jnp.asarray(_unit(rng, (n, cfg.dim))),
                          jnp.arange(n, dtype=jnp.int32))
        state = jax.tree_util.tree_map(
            lambda full, o: full.at[sess].set(o), state, one)
    return state


@pytest.mark.parametrize("dt", quant.DTYPES)
def test_wave_query_batched_matches_vmap_ref(dt):
    """Empty, partial, and full sessions in one wave, k > n_cached for
    most: the fused launch must match vmap(query) bitwise — ids, SLOT
    ORDER (stable top-k: empty slots ascend), LRU-stamp touches, step."""
    rng = np.random.default_rng(41)
    cfg = CacheConfig(capacity=24, dim=13, max_queries=4, store_dtype=dt)
    s = 4
    state = _filled_states(rng, cfg, s, [0, 3, 10, 24])
    psi = jnp.asarray(_unit(rng, (s, cfg.dim)))
    out_r, ref = C.query_batched(state, psi, 12, backend="ref")
    out_k, ker = C.query_batched(state, psi, 12, backend="interpret")
    _assert_query_equal(out_r, out_k)
    _assert_states_equal(ref, ker, f"query dt={dt}")
    # empty session answers all sentinels
    assert np.isneginf(np.asarray(out_k[0])[0]).all()
    assert (np.asarray(out_k[2])[0] == -1).all()


@pytest.mark.parametrize("dt", quant.DTYPES)
@pytest.mark.parametrize("eviction", ["none", "lru"])
def test_wave_insert_batched_matches_vmap_ref(dt, eviction):
    """Evict-while-append waves with per-session do/record masks and
    ring-wrapping query records: every post-insert state leaf must equal
    the vmap-of-scalar ref tier bitwise."""
    rng = np.random.default_rng(42)
    cfg = CacheConfig(capacity=16, dim=11, max_queries=3, store_dtype=dt,
                      eviction=eviction)
    s, kc = 5, 7
    ref = _filled_states(rng, cfg, s, [0, 4, 12, 16, 14])
    ker = ref
    for wave in range(5):                   # 5 waves: records wrap the ring
        psi = jnp.asarray(_unit(rng, (s, cfg.dim)))
        emb = jnp.asarray(_unit(rng, (s * kc, cfg.dim)).reshape(s, kc, -1))
        ids = jnp.asarray(rng.integers(0, 50, (s, kc)).astype(np.int32))
        radius = jnp.asarray(rng.uniform(0.4, 1.0, s).astype(np.float32))
        do = jnp.asarray(rng.integers(0, 2, s).astype(bool))
        rec = jnp.asarray(rng.integers(0, 2, s).astype(bool))
        ref, dr = C.insert_batched(ref, cfg, psi, radius, emb, ids,
                                   do=do, record=rec, backend="ref")
        ker, dk = C.insert_batched(ker, cfg, psi, radius, emb, ids,
                                   do=do, record=rec, backend="interpret")
        np.testing.assert_array_equal(np.asarray(dr), np.asarray(dk))
        _assert_states_equal(ref, ker, f"insert {dt}/{eviction} wave {wave}")


@pytest.mark.slow
@pytest.mark.parametrize("dt", quant.DTYPES)
def test_wave_insert_query_fused_matches_ref_sequence(dt):
    """The fused insert+query launch over mixed hit/miss waves must equal
    the ref-tier insert_batched -> query_batched sequence: query results
    (incl. slot order), dropped counts, and every state leaf."""
    rng = np.random.default_rng(43)
    cfg = CacheConfig(capacity=24, dim=12, max_queries=4, store_dtype=dt)
    s, kc, k = 5, 7, 6
    ref = C.init_batched_cache(cfg, s)
    ker = C.init_batched_cache(cfg, s)
    for wave in range(6):
        psi = jnp.asarray(_unit(rng, (s, cfg.dim)))
        emb = jnp.asarray(_unit(rng, (s * kc, cfg.dim)).reshape(s, kc, -1))
        ids = jnp.asarray(rng.integers(0, 40, (s, kc)).astype(np.int32))
        radius = jnp.asarray(rng.uniform(0.4, 1.0, s).astype(np.float32))
        do = (jnp.ones((s,), bool) if wave == 0 else
              jnp.asarray(rng.integers(0, 2, s).astype(bool)))
        rec = jnp.asarray(rng.integers(0, 2, s).astype(bool))
        out_r, ref, dr = C.insert_query_batched(
            ref, cfg, psi, radius, emb, ids, k, do=do, record=rec,
            backend="ref")
        out_k, ker, dk = C.insert_query_batched(
            ker, cfg, psi, radius, emb, ids, k, do=do, record=rec,
            backend="interpret")
        np.testing.assert_array_equal(np.asarray(dr), np.asarray(dk))
        _assert_query_equal(out_r, out_k)
        _assert_states_equal(ref, ker, f"fused dt={dt} wave {wave}")


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_wave_insert_do_false_leaves_lru_stamps_untouched(backend):
    """Regression (ISSUE 5 sweep): a do=False session's LRU stamps must
    survive an insert wave bitwise on BOTH tiers — the kernel scatter
    routes its positions to the drop sentinel, so nothing is written (a
    stamp refresh would shield the session's docs from LRU eviction)."""
    rng = np.random.default_rng(44)
    cfg = CacheConfig(capacity=16, dim=9, max_queries=4, eviction="lru")
    s, kc = 3, 5
    state = _filled_states(rng, cfg, s, [8, 8, 8])
    # distinct stamps via a query pass
    psi = jnp.asarray(_unit(rng, (s, cfg.dim)))
    _, state = C.query_batched(state, psi, 4, backend="ref")
    before = jax.tree_util.tree_map(np.asarray, state)
    do = jnp.asarray([True, False, True])
    state, _ = C.insert_batched(
        state, cfg, psi, jnp.asarray(np.full(s, 0.6, np.float32)),
        jnp.asarray(_unit(rng, (s * kc, cfg.dim)).reshape(s, kc, -1)),
        jnp.asarray(rng.integers(100, 200, (s, kc)).astype(np.int32)),
        do=do, backend=backend)
    after = jax.tree_util.tree_map(np.asarray, state)
    for name, a, b in zip(C.CacheState._fields, before, after):
        np.testing.assert_array_equal(
            a[1], b[1], err_msg=f"{backend}: do=False leaf {name} changed")
    assert int(after.step[0]) == int(before.step[0]) + 1   # do=True advanced


@pytest.mark.slow
def test_batched_engine_wave_is_three_launches_and_turn_identical(
        monkeypatch):
    """Acceptance (ISSUE 5): on the kernel tier a BatchedEngine wave with
    misses executes as EXACTLY three Pallas launches — probe ->
    miss-search -> fused insert+query, no vmap-of-scalar fallback — and
    its turns match the ref-tier engine on the same router."""
    import jax.experimental.pallas as plmod

    from repro.dist.retrieval import DeviceShard
    from repro.serve.router import ShardedRouter
    from repro.serve.session import BatchedEngine

    rng = np.random.default_rng(45)
    n, d, s = 600, 67, 4
    docs = _unit(rng, (n, d))
    # transformed geometry: unit rows are their own transform with an extra
    # zero column; keep it simple and treat docs as already transformed
    shard = DeviceShard(jnp.asarray(docs), jnp.arange(n, dtype=jnp.int32),
                        backend="interpret")
    # interpret-mode scans are slow; keep the deadline far away so the
    # router never degrades (degradation would skip the insert launch)
    router = ShardedRouter([shard], deadline_s=120.0)
    kw = dict(dim=d, n_sessions=s, k=9, k_c=53, capacity=160, epsilon=0.04)
    eng_k = BatchedEngine(router, docs, backend="interpret", **kw)
    eng_r = BatchedEngine(router, docs, backend="ref", **kw)

    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)

    # drop any compiled executables earlier tests left behind: the probe's
    # cache key is the PHYSICAL state shape (logical extents ride in as
    # mask arrays), so another test's engine with coincident phys extents
    # would otherwise satisfy the probe without tracing (= without being
    # counted), like the other launch guards do
    jax.clear_caches()

    base = _unit(rng, (s, d))
    for turn in range(3):
        queries = base + 0.02 * turn * _unit(rng, (s, d))
        queries = queries / np.linalg.norm(queries, axis=1, keepdims=True)
        qs = [jnp.asarray(q) for q in queries]
        calls["n"] = 0
        turns_k = eng_k.answer_batch(list(range(s)), qs)
        if turn == 0:
            # compulsory-miss wave, freshly cleared caches: every
            # kernel-tier cache op traces exactly one pallas_call —
            # 3 launches total
            assert calls["n"] == 3, f"wave traced {calls['n']} launches"
        turns_r = eng_r.answer_batch(list(range(s)), qs)
        for tk, tr in zip(turns_k, turns_r):
            assert tk.hit == tr.hit and tk.degraded == tr.degraded
            np.testing.assert_array_equal(tk.ids, tr.ids)
            np.testing.assert_allclose(tk.scores, tr.scores,
                                       rtol=1e-5, atol=1e-5)
