"""Continuous-batching scheduler tests: admission, adaptive sizing,
per-slot drain, telemetry, outage behavior, and launch-count guards."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metric_index import MetricIndex
from repro.data.conversations import WorldConfig, make_world
from repro.serve.engine import ConversationalEngine, EngineTurn
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.session import BatchedEngine, SessionManager
from repro.serve.telemetry import (EwmaRate, RingPercentiles, ServeTelemetry,
                                   TurnSpans)

jax.config.update("jax_platform_name", "cpu")

WORLD = WorldConfig(n_topics=4, docs_per_topic=200, n_background=800,
                    dim=64, subspace_dim=8, turns=4, n_conversations=4,
                    doc_sigma=0.6, query_sigma=0.12, drift_sigma=0.16,
                    subtopic_prob=0.35, subtopic_sigma=0.75, seed=9)


@pytest.fixture(scope="module")
def world():
    return make_world(WORLD)


@pytest.fixture(scope="module")
def index(world):
    return MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))


def make_shards(index, n_shards, fail=()):
    docs = np.asarray(index.doc_emb[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did, i=i):
            if i in fail:
                raise RuntimeError(f"shard {i} down")
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def _streams(world, index, n_sessions):
    convs = world.conversations
    return [np.asarray(index.transform_queries(
        jnp.asarray(convs[s % len(convs)].queries, jnp.float32)))
        for s in range(n_sessions)]


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


# --------------------------------------------------------------- telemetry
def test_ring_percentiles_window_and_nearest_rank():
    ring = RingPercentiles(capacity=4)
    assert np.isnan(ring.percentile(50))
    for x in range(1, 11):
        ring.add(float(x))
    assert len(ring) == 4 and ring.count == 10      # window holds last 4
    assert ring.percentile(50) == 8.0               # nearest rank of 7..10
    assert ring.percentile(99) == 10.0
    s = ring.summary()
    assert s["count"] == 10 and s["p50"] == 8.0 and s["p99"] == 10.0


def test_ewma_rate_converges_and_decays_on_silence():
    t = [0.0]
    r = EwmaRate(horizon_s=0.05, clock=lambda: t[0])
    for _ in range(50):                             # steady 10 events/sec
        t[0] += 0.1
        r.observe()
    assert r.rate() == pytest.approx(10.0, rel=0.05)
    t[0] += 0.5                                     # silence -> decay
    assert r.rate() < 1.0
    assert r.count == 50


def test_serve_telemetry_records_spans_and_tiers():
    tel = ServeTelemetry()
    tel.record_turn(TurnSpans(queue_wait_s=0.01, probe_s=0.002,
                              backend_s=0.05, insert_s=0.003,
                              total_s=0.065, tier="backend"))
    tel.record_turn(TurnSpans(total_s=0.004, tier="l1"))
    tel.record_wave(2, 0.06)
    s = tel.summary()
    assert s["turns"] == 2 and s["waves"] == 1
    assert s["spans"]["total_s"]["count"] == 2
    assert set(s["tiers"]) == {"backend", "l1"}
    assert s["wave_size"]["p50"] == 2.0


# ----------------------------------------------------------- sizing policy
def test_target_limit_little_law_pow2_and_clamps():
    sched = ContinuousScheduler(fn=lambda xs: xs, min_wave=1, max_wave=64,
                                adaptive=False)
    try:
        # 100/s x 20ms x 1.5 headroom = 3 turns -> next pow2 bucket = 4
        assert sched._target_limit(100.0, 0.02) == 4
        assert sched._target_limit(0.0, 0.02) == 1          # min clamp
        assert sched._target_limit(1e9, 1.0) == 64          # max clamp
    finally:
        sched.close()


def test_target_limit_p99_overshoot_backs_off():
    sched = ContinuousScheduler(fn=lambda xs: xs, max_wave=64,
                                adaptive=False, target_p99_s=0.05)
    try:
        sched.wave_limit = 32
        # demand says 64, but measured p99 is over target: halve instead
        assert sched._target_limit(1e9, 1.0, p99_s=0.1) == 16
        # p99 under target: demand wins
        assert sched._target_limit(1e9, 1.0, p99_s=0.01) == 64
    finally:
        sched.close()


def test_adapt_sizes_wave_limit_from_arrival_ewma():
    sched = ContinuousScheduler(fn=lambda xs: xs, max_wave=64, adaptive=True)
    try:
        t = [0.0]
        sched.telemetry.arrivals = EwmaRate(horizon_s=0.02,
                                            clock=lambda: t[0])
        for _ in range(20):                         # 100 arrivals/sec
            t[0] += 0.01
            sched.telemetry.arrivals.observe()
        sched._service_ewma = 0.02
        with sched._cond:
            sched._adapt_locked()
        assert sched.wave_limit == 4                # 100/s x 20ms x 1.5
    finally:
        sched.close()


def test_adapt_holds_cold_start_below_min_arrivals():
    sched = ContinuousScheduler(fn=lambda xs: xs, max_wave=32, adaptive=True)
    try:
        sched._service_ewma = 0.02
        with sched._cond:
            sched._adapt_locked()                   # no arrivals yet
        assert sched.wave_limit == 32               # cold start untouched
    finally:
        sched.close()


# -------------------------------------------------------- fn-mode admission
def test_continuous_admission_needs_no_window_or_full_batch():
    """The continuous default: a lone arrival executes as soon as the
    worker can take it — no window timer, no batch-full threshold."""
    with ContinuousScheduler(fn=lambda xs: [x * 2 for x in xs]) as sched:
        t0 = time.monotonic()
        assert sched.submit(21).result(timeout=5) == 42
        assert time.monotonic() - t0 < 2.0


def test_flush_waits_for_inflight_wave():
    def fn(items):
        time.sleep(0.2)
        return items

    with ContinuousScheduler(fn=fn) as sched:
        fut = sched.submit(1)
        time.sleep(0.05)                            # wave now in flight
        sched.flush()
        assert fut.done() and fut.result() == 1


def test_same_slot_arrivals_defer_to_later_waves():
    calls = []

    def fn(items):
        calls.append(list(items))
        time.sleep(0.05)
        return items

    with ContinuousScheduler(fn=fn, window_s=60.0, adaptive=False,
                             max_wave=8) as sched:
        futs = [sched.submit(f"a{i}", slot="a") for i in range(3)]
        sched.flush()
        assert [f.result(timeout=5) for f in futs] == ["a0", "a1", "a2"]
    # one in-flight turn per slot: three sub-waves, in admission order
    assert calls == [["a0"], ["a1"], ["a2"]]


def test_drain_slot_executes_only_that_slot():
    """Per-slot drain (the SessionManager.close satellite): draining slot
    'a' bypasses the window hold for a's turns ONLY — slot b's queued turn
    keeps waiting on its own schedule."""
    calls = []

    def fn(items):
        calls.append(list(items))
        return items

    with ContinuousScheduler(fn=fn, window_s=60.0, adaptive=False,
                             max_wave=8) as sched:
        fa = sched.submit("a1", slot="a")
        fb = sched.submit("b1", slot="b")
        sched.drain_slot("a")
        assert fa.result(timeout=5) == "a1"
        assert not fb.done()                        # untouched by the drain
        assert calls == [["a1"]]
        sched.flush()
        assert fb.result(timeout=5) == "b1"


def test_scheduler_rejects_ambiguous_modes():
    with pytest.raises(ValueError, match="exactly one"):
        ContinuousScheduler()
    with pytest.raises(ValueError, match="min_wave"):
        ContinuousScheduler(fn=lambda xs: xs, min_wave=9, max_wave=4)


# ------------------------------------------------------- engine-mode waves
def test_queue_wait_is_attributed_per_turn(world, index):
    """Satellite: latency is admission-to-resolution per turn.  A second
    turn of the same session defers behind the first wave, so its queue
    wait is visible — and a directly-invoked wave has none."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=1, k=5, k_c=60)
    qs = _streams(world, index, 1)[0]
    with ContinuousScheduler(eng) as sched:
        eng.start_session(0)
        f1 = sched.submit(qs[0], slot=0)
        f2 = sched.submit(qs[1], slot=0)            # defers behind wave 1
        t1, t2 = f1.result(timeout=30), f2.result(timeout=30)
    assert t2.queue_wait_s > 0.0
    for t in (t1, t2):
        assert t.latency_s >= t.queue_wait_s >= 0.0
        assert t.spans is not None and t.spans.total_s == t.latency_s
    direct = eng.answer_batch([0], [qs[2]])[0]
    assert direct.queue_wait_s == 0.0
    # telemetry recorded every resolved turn's spans
    assert eng.telemetry.spans["total_s"].count >= 3


def test_session_manager_close_drains_only_its_key(world, index):
    """Satellite: close(key) no longer flushes the global batcher — another
    session's held turn stays queued through the close."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=3, k=5, k_c=60)
    qs = _streams(world, index, 2)
    with SessionManager(eng, window_s=60.0, max_batch=2) as mgr:
        mgr.open("a")
        mgr.open("b")
        fa = mgr.submit("a", qs[0][0])
        fb = mgr.submit("b", qs[1][0])
        # wave fires (full at max_batch=2); drain both to an idle queue
        assert isinstance(fa.result(timeout=30), EngineTurn)
        assert isinstance(fb.result(timeout=30), EngineTurn)
        fb2 = mgr.submit("b", qs[1][1])             # held by the 60s window
        mgr.close("a")                              # a has nothing pending
        assert not fb2.done()                       # b's turn NOT flushed
        mgr.flush()
        assert isinstance(fb2.result(timeout=30), EngineTurn)


def test_close_runs_pending_turn_before_slot_recycle(world, index):
    """A turn already submitted for a closing key executes during close
    (against the right cache), never against the slot's next occupant."""
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=1, k=5, k_c=60)
    qs = _streams(world, index, 1)[0]
    with SessionManager(eng, window_s=60.0, max_batch=4) as mgr:
        mgr.open("a")
        fut = mgr.submit("a", qs[0])                # held by the window
        mgr.close("a")                              # per-slot drain runs it
        assert isinstance(fut.result(timeout=1), EngineTurn)
        slot = mgr.open("b")
        assert slot == 0 and eng.cache.n_docs[0] == 0


def test_outage_fails_only_empty_cache_sessions_and_loop_survives(
        world, index):
    """Satellite: a backend TimeoutError mid-wave fails only the sessions
    whose cache is still empty; warm sessions answer from cache, and the
    scheduler loop keeps serving afterwards (never wedges).

    Breaker tripping is disabled here so the swapped-back shards answer
    the very next wave — this test pins the scheduler-loop contract;
    breaker-fenced outage + cooldown recovery through the scheduler is
    tests/test_faults.py's scheduler recovery test."""
    router = ShardedRouter(make_shards(index, 2), deadline_s=10,
                           breaker_min_calls=10**9)
    eng = BatchedEngine(router, np.asarray(index.doc_emb), dim=index.dim,
                        n_sessions=2, k=5, k_c=80)
    streams = _streams(world, index, 2)
    eng.start_session(0)
    eng.start_session(1)
    eng.answer_batch([0], [streams[0][0]])          # warm only session 0
    router.shards = make_shards(index, 2, fail={0, 1})
    with ContinuousScheduler(eng, window_s=60.0, adaptive=False) as sched:
        # both queued -> one wave (fires full at max_wave = n_sessions = 2)
        fa = sched.submit(streams[0][1], slot=0)
        fc = sched.submit(streams[1][0], slot=1)
        ta = fa.result(timeout=30)
        assert isinstance(ta, EngineTurn) and (ta.degraded or ta.hit)
        with pytest.raises(TimeoutError):
            fc.result(timeout=30)
        assert len(eng.turns[1]) == 0               # failed turn unrecorded
        # an all-empty-cache wave raises for every waiter...
        eng.start_session(0)
        eng.start_session(1)
        f1 = sched.submit(streams[0][0], slot=0)
        f2 = sched.submit(streams[1][0], slot=1)
        for f in (f1, f2):
            with pytest.raises(TimeoutError):
                f.result(timeout=30)
        # ...and the loop is still alive once the backend recovers
        router.shards = make_shards(index, 2)
        f3 = sched.submit(streams[0][0], slot=0)
        f4 = sched.submit(streams[1][0], slot=1)
        t3, t4 = f3.result(timeout=30), f4.result(timeout=30)
        assert isinstance(t3, EngineTurn) and not t3.degraded
        assert isinstance(t4, EngineTurn) and not t4.degraded


@pytest.mark.slow
def test_scheduler_turns_match_sequential_engine(world, index):
    """Acceptance: turns served through the continuous scheduler (probe
    overlap on) are bit-identical per session to a sequential
    ConversationalEngine loop over the same streams."""
    S, T, k, k_c = 3, 3, 8, 80
    doc = np.asarray(index.doc_emb)
    seq_router = ShardedRouter(make_shards(index, 2), deadline_s=30)
    seq = [ConversationalEngine(seq_router, doc, dim=index.dim, k=k,
                                k_c=k_c) for _ in range(S)]
    for e in seq:
        e.start_session()
    eng = BatchedEngine(ShardedRouter(make_shards(index, 2), deadline_s=30),
                        doc, dim=index.dim, n_sessions=S, k=k, k_c=k_c)
    streams = _streams(world, index, S)
    with SessionManager(eng, overlap=True) as mgr:  # continuous: window 0
        for s in range(S):
            mgr.open(s)
        for t in range(T):
            futs = [mgr.submit(s, streams[s][t]) for s in range(S)]
            for s, fut in enumerate(futs):
                turn = fut.result(timeout=60)
                ref = seq[s].answer(streams[s][t])
                np.testing.assert_array_equal(ref.ids, turn.ids)
                np.testing.assert_array_equal(ref.scores, turn.scores)
                assert ref.hit == turn.hit


@pytest.mark.slow
def test_scheduler_wave_launch_guards_hold_through_outage(monkeypatch):
    """Satellite: the per-wave kernel-launch contract survives the
    scheduler refactor AND a mid-run outage — a compulsory-miss wave is
    exactly 3 Pallas launches (probe -> miss-search -> insert+query), an
    outage wave exactly 2 (probe -> cache-fallback query; nothing to
    insert), counted through the scheduler's worker, not answer_batch."""
    import jax.experimental.pallas as plmod

    from repro.dist.retrieval import DeviceShard

    rng = np.random.default_rng(46)
    n, d, s = 500, 61, 4
    docs = _unit(rng, (n, d))
    dev = DeviceShard(jnp.asarray(docs), jnp.arange(n, dtype=jnp.int32),
                      backend="interpret")
    down = {"on": False}

    def shard(queries, k):
        if down["on"]:
            raise RuntimeError("shard down")
        return dev(queries, k)

    router = ShardedRouter([shard], deadline_s=120.0)
    eng = BatchedEngine(router, docs, backend="interpret", dim=d,
                        n_sessions=s, k=7, k_c=41, capacity=120)

    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)

    base = _unit(rng, (s, d))
    with ContinuousScheduler(eng, window_s=60.0, adaptive=False) as sched:
        for i in range(s):
            eng.start_session(i)
        jax.clear_caches()
        calls["n"] = 0
        futs = [sched.submit(jnp.asarray(base[i]), slot=i) for i in range(s)]
        turns = [f.result(timeout=600) for f in futs]
        assert calls["n"] == 3, f"miss wave traced {calls['n']} launches"
        assert all(not t.hit for t in turns)

        down["on"] = True
        q2 = base + 0.5 * _unit(rng, (s, d))
        q2 /= np.linalg.norm(q2, axis=1, keepdims=True)
        jax.clear_caches()
        calls["n"] = 0
        futs = [sched.submit(jnp.asarray(q2[i]), slot=i) for i in range(s)]
        turns = [f.result(timeout=600) for f in futs]
        assert calls["n"] == 2, f"outage wave traced {calls['n']} launches"
        for t in turns:
            assert isinstance(t, EngineTurn) and (t.degraded or t.hit)
        down["on"] = False
