"""Unit + property tests for the paper's core: transform, index, cache, driver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embedding as emb
from repro.core.cache import CacheConfig, MetricCache, init_cache, probe
from repro.core.conversation import ConversationalSearcher
from repro.core.metric_index import MetricIndex, exact_nn

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- transform
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dim", [8, 64, 768])
def test_mips_l2_equivalence(seed, dim):
    """Property (paper Eq. 1): argsort by inner product == argsort by -L2."""
    rng = np.random.default_rng(seed)
    docs = rng.standard_normal((200, dim)) * rng.uniform(0.5, 2.0, (200, 1))
    q = rng.standard_normal((3, dim))
    docs_t, m = emb.transform_documents(jnp.asarray(docs))
    q_t = emb.transform_queries(jnp.asarray(q))
    # unit-norm check
    np.testing.assert_allclose(np.linalg.norm(docs_t, axis=1), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(q_t, axis=1), 1.0, atol=1e-5)
    ip_rank = np.argsort(-(q @ docs.T), axis=1)
    d = np.asarray(emb.pairwise_distances(q_t, docs_t))
    l2_rank = np.argsort(d, axis=1)
    np.testing.assert_array_equal(ip_rank[:, :20], l2_rank[:, :20])


def test_transform_incremental_batches_share_m():
    rng = np.random.default_rng(0)
    docs = rng.standard_normal((100, 16))
    all_t, m = emb.transform_documents(jnp.asarray(docs))
    part_t, _ = emb.transform_documents(jnp.asarray(docs[:50]), max_norm=m)
    np.testing.assert_allclose(np.asarray(all_t[:50]), np.asarray(part_t), atol=1e-6)


# ---------------------------------------------------------------- index
@pytest.mark.parametrize("n,chunk", [(100, 32), (256, 64), (1000, 128)])
def test_chunked_equals_exact(n, chunk):
    rng = np.random.default_rng(1)
    docs = rng.standard_normal((n, 32)).astype(np.float32)
    q = rng.standard_normal((5, 32)).astype(np.float32)
    idx = MetricIndex(jnp.asarray(docs), chunk=chunk)
    qt = idx.transform_queries(jnp.asarray(q))
    res = idx.search(qt, k=10)
    ref = exact_nn(idx.doc_emb[:n], idx.doc_ids[:n], qt, 10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(np.asarray(res.scores), np.asarray(ref.scores), rtol=1e-5)


def test_index_distances_sorted_ascending():
    rng = np.random.default_rng(2)
    idx = MetricIndex(jnp.asarray(rng.standard_normal((300, 16)).astype(np.float32)))
    qt = idx.transform_queries(jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32)))
    res = idx.search(qt, k=25)
    d = np.asarray(res.distances)
    assert (np.diff(d, axis=1) >= -1e-6).all()


# ---------------------------------------------------------------- cache ops
def _mini_world(seed=0, n=500, dim=24):
    rng = np.random.default_rng(seed)
    docs = rng.standard_normal((n, dim)).astype(np.float32)
    idx = MetricIndex(jnp.asarray(docs))
    return rng, idx


def test_cache_probe_empty_is_miss():
    cfg = CacheConfig(capacity=64, dim=25)
    st = init_cache(cfg)
    pr = probe(st, jnp.ones((25,)) / 5.0, cfg.epsilon)
    assert not bool(pr.hit) and int(pr.nearest_q) == -1


def test_cache_insert_query_roundtrip_and_dedup():
    rng, idx = _mini_world()
    cfg = CacheConfig(capacity=128, dim=idx.dim)
    cache = MetricCache(cfg)
    q = idx.transform_queries(jnp.asarray(rng.standard_normal(24).astype(np.float32)))
    res = idx.search(q[None], 50)
    docs = idx.doc_emb[res.ids[0]]
    cache.insert(q, res.distances[0, -1], docs, res.ids[0])
    assert cache.n_docs == 50 and cache.n_queries == 1
    # idempotent re-insert (dedup)
    cache.insert(q, res.distances[0, -1], docs, res.ids[0])
    assert cache.n_docs == 50
    (scores, dists, ids, _) = cache.query(q, 10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(res.ids[0, :10]))


def test_cache_hit_guarantee():
    """Metric-space guarantee: if psi falls r_hat>=0 inside a cached ball, the
    docs within the inner ball returned from cache are the exact global NNs."""
    rng, idx = _mini_world(seed=3, n=800)
    cfg = CacheConfig(capacity=512, dim=idx.dim)
    cache = MetricCache(cfg)
    base = rng.standard_normal(24).astype(np.float32)
    qa = idx.transform_queries(jnp.asarray(base))
    res = idx.search(qa[None], 400)
    cache.insert(qa, res.distances[0, -1], idx.doc_emb[res.ids[0]], res.ids[0])
    # nearby query
    qb = idx.transform_queries(jnp.asarray(base + 0.05 * rng.standard_normal(24).astype(np.float32)))
    pr = cache.probe(qb, epsilon=0.0)
    assert bool(pr.hit)
    (_, dists, ids, _) = cache.query(qb, 5)
    exact = idx.search(qb[None], 5)
    r_hat = float(pr.r_hat)
    # every returned doc strictly inside the inner ball must be exact
    inner = np.asarray(dists) <= r_hat + 1e-6
    np.testing.assert_array_equal(np.asarray(ids)[inner], np.asarray(exact.ids[0])[inner])


def test_cache_overflow_drops_and_counts():
    rng, idx = _mini_world()
    cfg = CacheConfig(capacity=30, dim=idx.dim)
    cache = MetricCache(cfg)
    q = idx.transform_queries(jnp.asarray(rng.standard_normal(24).astype(np.float32)))
    res = idx.search(q[None], 50)
    cache.insert(q, res.distances[0, -1], idx.doc_emb[res.ids[0]], res.ids[0])
    assert cache.n_docs == 30 and cache.total_dropped == 20


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_cache_eviction_keeps_capacity(eviction):
    rng, idx = _mini_world(seed=5)
    cfg = CacheConfig(capacity=64, dim=idx.dim, eviction=eviction)
    cache = MetricCache(cfg)
    for i in range(4):
        q = idx.transform_queries(jnp.asarray(rng.standard_normal(24).astype(np.float32)))
        res = idx.search(q[None], 40)
        cache.insert(q, res.distances[0, -1], idx.doc_emb[res.ids[0]], res.ids[0])
        (_, _, ids, _) = cache.query(q, 10)
        assert (np.asarray(ids) >= 0).all()
    assert cache.n_docs <= 64


def _unit_rows(rng, n, dim):
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_eviction_overflow_no_duplicates_no_clobber(eviction):
    """Invariants of a single overflowing insert under the beyond-paper
    eviction policies: occupied slots hold unique doc ids, and a slot the
    call appends to is never also an eviction target of the same call."""
    dim, cap = 8, 32
    cfg = CacheConfig(capacity=cap, dim=dim, eviction=eviction)
    cache = MetricCache(cfg)
    rng = np.random.default_rng(0)

    psi0 = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi0, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 20, dim)),
                 jnp.arange(20, dtype=jnp.int32))
    assert cache.n_docs == 20

    # overflowing batch with an intra-batch duplicate and an already-cached id
    new_ids = np.arange(100, 120, dtype=np.int32)
    new_ids[5] = 100       # duplicate of new_ids[0] within the batch
    new_ids[7] = 3         # already cached
    new_emb = _unit_rows(rng, 20, dim)
    psi1 = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi1, jnp.asarray(0.9, jnp.float32), jnp.asarray(new_emb),
                 jnp.asarray(new_ids))

    st = cache.state
    ids = np.asarray(st.doc_ids)
    occupied = ids[ids >= 0]
    assert cache.n_docs == cap and occupied.size == cap
    # 1) no duplicate doc ids anywhere in the cache
    assert np.unique(occupied).size == occupied.size
    # 2) every deduplicated new doc landed and its slot was not clobbered
    #    by an eviction write of the same call
    expected = {int(i) for j, i in enumerate(new_ids) if j not in (5, 7)}
    assert expected <= set(occupied.tolist())
    doc_emb = np.asarray(st.doc_emb)
    for j, did in enumerate(new_ids):
        if j in (5, 7):
            continue
        slot = int(np.nonzero(ids == did)[0][0])
        np.testing.assert_array_equal(doc_emb[slot, :dim], new_emb[j])


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_eviction_full_cache_overflow_stays_consistent(eviction):
    """Overflow into an already-full cache: every write is an eviction."""
    dim, cap = 8, 16
    cfg = CacheConfig(capacity=cap, dim=dim, eviction=eviction)
    cache = MetricCache(cfg)
    rng = np.random.default_rng(1)
    psi = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, cap, dim)),
                 jnp.arange(cap, dtype=jnp.int32))
    assert cache.n_docs == cap
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 10, dim)),
                 jnp.arange(100, 110, dtype=jnp.int32))
    ids = np.asarray(cache.state.doc_ids)
    occupied = ids[ids >= 0]
    assert cache.n_docs == cap and occupied.size == cap
    assert np.unique(occupied).size == occupied.size
    assert {int(i) for i in range(100, 110)} <= set(occupied.tolist())


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_eviction_insert_straddling_capacity_no_self_clobber(eviction):
    """Regression: an insert straddling the capacity boundary must append
    and evict to disjoint slots — the old position assignment indexed evict
    targets from the front of the staleness order (empty tail slots first),
    clobbering its own freshly appended docs once the batch spilled past
    the order's occupied region."""
    dim, cap = 8, 16
    cache = MetricCache(CacheConfig(capacity=cap, dim=dim, eviction=eviction))
    rng = np.random.default_rng(0)
    psi = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 10, dim)),
                 jnp.arange(10, dtype=jnp.int32))
    # 20 new docs into 6 free slots: 6 append, 10 evict, 4 genuinely cannot
    # fit (the batch alone exceeds capacity) and must be counted as dropped
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 20, dim)),
                 jnp.arange(100, 120, dtype=jnp.int32))
    ids = np.asarray(cache.state.doc_ids)
    occupied = ids[ids >= 0]
    assert cache.n_docs == cap and occupied.size == cap
    assert np.unique(occupied).size == occupied.size
    landed = [i for i in range(100, 120) if i in occupied]
    assert len(landed) == cap                 # old code lost part of the batch
    assert cache.total_dropped == 20 - cap


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_eviction_partial_overflow_keeps_whole_batch(eviction):
    """A batch that straddles capacity but fits overall loses nothing."""
    dim, cap = 8, 16
    cache = MetricCache(CacheConfig(capacity=cap, dim=dim, eviction=eviction))
    rng = np.random.default_rng(1)
    psi = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 12, dim)),
                 jnp.arange(12, dtype=jnp.int32))
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 10, dim)),
                 jnp.arange(100, 110, dtype=jnp.int32))
    ids = np.asarray(cache.state.doc_ids)
    occupied = ids[ids >= 0]
    assert cache.n_docs == cap and occupied.size == cap
    assert np.unique(occupied).size == occupied.size
    assert {int(i) for i in range(100, 110)} <= set(occupied.tolist())
    assert cache.total_dropped == 0


@pytest.mark.parametrize("eviction", ["lru", "ball"])
def test_eviction_never_evicts_docs_rejoined_by_same_batch(eviction):
    """A doc whose id appears in the incoming batch is part of the
    (psi, r_a) claim being recorded: dedup keeps it out of the batch
    *because* it is cached, so the same call must not evict it."""
    dim, cap = 8, 8
    cache = MetricCache(CacheConfig(capacity=cap, dim=dim, eviction=eviction))
    rng = np.random.default_rng(6)
    psi = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, cap, dim)),
                 jnp.arange(cap, dtype=jnp.int32))
    # full cache; new answer re-retrieves cached id 0 plus 7 fresh docs
    new_ids = np.asarray([0, 100, 101, 102, 103, 104, 105, 106], np.int32)
    cache.insert(jnp.asarray(_unit_rows(rng, 1, dim)[0]),
                 jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 8, dim)), jnp.asarray(new_ids))
    occupied = set(np.asarray(cache.state.doc_ids).tolist())
    assert 0 in occupied                      # the re-claimed doc survived
    assert {100, 101, 102, 103, 104, 105, 106} <= occupied
    assert cache.n_docs == cap


def test_query_slots_ring_overwrite_oldest():
    """Past max_queries inserts, the ring overwrites the *oldest* record —
    the old clamp kept slot max_queries-1 forever, losing the newest."""
    dim = 8
    cfg = CacheConfig(capacity=256, dim=dim, max_queries=4)
    cache = MetricCache(cfg)
    rng = np.random.default_rng(2)
    for i in range(6):
        cache.insert(jnp.asarray(_unit_rows(rng, 1, dim)[0]),
                     jnp.asarray(float(i), jnp.float32),
                     jnp.asarray(_unit_rows(rng, 2, dim)),
                     jnp.arange(2 * i, 2 * i + 2, dtype=jnp.int32))
    assert cache.n_queries == 4 and cache.total_queries == 6
    # slots 0,1 held queries 0,1 — overwritten by 4,5; slots 2,3 survive
    # (the ring is allocated longer — phys_max_queries — but only the
    # logical max_queries=4 slots are ever written)
    np.testing.assert_array_equal(np.asarray(cache.state.q_radius)[:4],
                                  np.asarray([4.0, 5.0, 2.0, 3.0], np.float32))


def test_query_slots_ring_probe_reflects_newest():
    """The most recent query must stay probe-able after the ring wraps."""
    dim = 8
    cfg = CacheConfig(capacity=256, dim=dim, max_queries=4)
    cache = MetricCache(cfg)
    rng = np.random.default_rng(3)
    psis = _unit_rows(rng, 6, dim)
    for i in range(6):
        cache.insert(jnp.asarray(psis[i]), jnp.asarray(0.5, jnp.float32),
                     jnp.asarray(_unit_rows(rng, 2, dim)),
                     jnp.arange(2 * i, 2 * i + 2, dtype=jnp.int32))
    # probing exactly the newest recorded query: ~zero self-distance (sqrt
    # of float32 rounding leaves ~3e-4), so r_hat ~= r_a
    pr = cache.probe(jnp.asarray(psis[5]), epsilon=0.4)
    assert bool(pr.hit) and abs(float(pr.r_hat) - 0.5) < 1e-3
    # the oldest queries were evicted from the ring: a re-probe of query 0
    # no longer finds its own record (distance-0 self-match), so its best
    # r_hat drops below the self-match value of 0.5
    pr_old = cache.probe(jnp.asarray(psis[0]), epsilon=0.4)
    assert float(pr_old.r_hat) < 0.5 - 1e-3 and not bool(pr_old.hit)


def test_insert_record_false_keeps_docs_skips_query_record():
    """Degraded back-end answers: docs are cached, (psi, r_a) is not."""
    rng, idx = _mini_world()
    cache = MetricCache(CacheConfig(capacity=128, dim=idx.dim))
    q = idx.transform_queries(jnp.asarray(rng.standard_normal(24).astype(np.float32)))
    res = idx.search(q[None], 50)
    cache.insert(q, res.distances[0, -1], idx.doc_emb[res.ids[0]], res.ids[0],
                 record=False)
    assert cache.n_docs == 50 and cache.n_queries == 0
    assert not bool(cache.probe(q).hit)       # no record -> no coverage claim


def test_insert_ignores_sentinel_ids():
    """ids < 0 are merge padding, never inserted — even into a full cache."""
    dim = 8
    cache = MetricCache(CacheConfig(capacity=16, dim=dim))
    rng = np.random.default_rng(4)
    psi = jnp.asarray(_unit_rows(rng, 1, dim)[0])
    ids = np.arange(8, dtype=np.int32)
    ids[5:] = -1
    cache.insert(psi, jnp.asarray(0.9, jnp.float32),
                 jnp.asarray(_unit_rows(rng, 8, dim)), jnp.asarray(ids))
    assert cache.n_docs == 5
    assert (np.asarray(cache.state.doc_ids) >= 0).sum() == 5


# ---------------------------------------------------------------- driver
def test_conversation_first_turn_always_miss():
    _, idx = _mini_world()
    s = ConversationalSearcher(index=idx, k=5, k_c=100)
    s.start_conversation()
    rng = np.random.default_rng(7)
    rec = s.answer(idx.transform_queries(jnp.asarray(rng.standard_normal(24).astype(np.float32))))
    assert not rec.hit and rec.cache_docs == 100


def test_static_policy_never_updates():
    rng, idx = _mini_world()
    s = ConversationalSearcher(index=idx, k=5, k_c=100, policy="static")
    s.start_conversation()
    base = rng.standard_normal(24).astype(np.float32)
    for t in range(5):
        q = idx.transform_queries(jnp.asarray(base + 0.3 * t * rng.standard_normal(24).astype(np.float32)))
        s.answer(q)
    assert s.cache.n_queries == 1 and s.hit_rate() == 1.0


def test_dynamic_policy_updates_on_topic_shift():
    rng, idx = _mini_world(seed=11, n=1000)
    s = ConversationalSearcher(index=idx, k=5, k_c=50, epsilon=0.04)
    s.start_conversation()
    a = rng.standard_normal(24).astype(np.float32)
    b = -a  # antipodal topic
    s.answer(idx.transform_queries(jnp.asarray(a)))
    rec = s.answer(idx.transform_queries(jnp.asarray(b)))
    assert not rec.hit  # far query must trigger an update
    assert s.cache.n_queries == 2


def test_query_short_cache_sentinels_and_untouched_stamps():
    """Regression: a cache holding fewer than k docs answers with (id -1,
    score -inf) sentinel slots, and the LRU stamp touch used to refresh
    those *empty* slots' stamps — making LRU eviction prefer overwriting
    live documents over reusing untouched empty slots."""
    rng = np.random.default_rng(0)
    cfg = CacheConfig(capacity=6, dim=8, eviction="lru")
    cache = MetricCache(cfg)
    psi = jnp.asarray(rng.standard_normal(8), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
    cache.insert(psi, 0.5, docs, jnp.asarray([7, 9], jnp.int32))

    scores, _dists, ids, _slots = cache.query(psi, 5)
    ids, scores = np.asarray(ids), np.asarray(scores)
    assert (ids[:2] >= 0).all()
    np.testing.assert_array_equal(ids[2:], -1)
    assert np.isneginf(scores[2:]).all()

    stamps = np.asarray(cache.state.doc_stamp)
    # insert stamped slots 0-1 at step 0; the query touched them at step 1;
    # the four empty slots must still read 0, not the query step
    np.testing.assert_array_equal(stamps[:2], 1)
    np.testing.assert_array_equal(stamps[2:], 0)
