"""Fault-domain resilience tests: the deterministic fault injector, answer
validation, per-shard circuit breakers, router retry/backoff, the
degradation ladder (load-shed waves, stale-while-error memo serves, the
cache-state integrity guard), the RouterStats lock, router lifecycle, and
the 2-launch shed-wave kernel contract."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_ops import validate_state
from repro.core.metric_index import MetricIndex
from repro.core.shared import SharedTier
from repro.serve.engine import EngineTurn
from repro.serve.faults import (CORRUPT_MODES, FaultError, FaultPlan,
                                FaultSpec, FaultyShard, _corrupt, chaos_plan)
from repro.serve.router import (AnswerValidationError, CircuitBreaker,
                                ShardAnswer, ShardedRouter, validate_answer)
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.session import BatchedEngine
from repro.serve.telemetry import ServeTelemetry

jax.config.update("jax_platform_name", "cpu")

N_DOCS, DIM = 240, 32


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(3)
    raw = rng.standard_normal((N_DOCS, DIM)).astype(np.float32)
    return MetricIndex(jnp.asarray(raw))


@pytest.fixture(scope="module")
def docs(index):
    return np.asarray(index.dequantized()[:index.n_docs])


def make_shards(index, n_shards):
    docs = np.asarray(index.dequantized()[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did):
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def queries_for(index, n, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((n, DIM)).astype(np.float32)
    return np.asarray(index.transform_queries(jnp.asarray(q)))


# ------------------------------------------------------------ fault injector
def test_fault_spec_schedule_windows_and_flapping():
    solid = FaultSpec("error", start=3, stop=6)
    assert [solid.active(c) for c in range(8)] == \
        [False] * 3 + [True] * 3 + [False] * 2
    flap = FaultSpec("latency", start=2, period=3, width=1, delay_s=0.01)
    assert [flap.active(c) for c in range(2, 8)] == \
        [True, False, False, True, False, False]
    open_ended = FaultSpec("corrupt", start=5)
    assert not open_ended.active(4) and open_ended.active(10 ** 6)
    with pytest.raises(ValueError):
        FaultSpec("meteor")
    with pytest.raises(ValueError):
        FaultSpec("error", period=2, width=3)
    with pytest.raises(ValueError):
        FaultSpec("corrupt", mode="garbled")


def test_faulty_shard_applies_each_kind(index):
    inner = make_shards(index, 1)[0]
    q = queries_for(index, 2)

    lat = FaultyShard(inner, [FaultSpec("latency", stop=1, delay_s=0.05)])
    t0 = time.perf_counter()
    lat(q, 5)
    assert time.perf_counter() - t0 >= 0.05
    t0 = time.perf_counter()
    lat(q, 5)                                     # past the window: no sleep
    assert time.perf_counter() - t0 < 0.04

    err = FaultyShard(inner, [FaultSpec("error", stop=1)])
    with pytest.raises(FaultError):
        err(q, 5)
    err(q, 5)                                     # recovers after the window
    assert err.calls == 2 and err.faults == 1

    bad = FaultyShard(inner, [FaultSpec("corrupt", mode="nan")])
    assert np.isnan(bad(q, 5).scores).any()

    clean = FaultyShard(inner)                    # spec-less: transparent
    ans = clean(q, 5)
    validate_answer(ans, 2, 5, index.n_docs)
    assert clean.calls == 1 and clean.faults == 0


def test_fault_plan_is_deterministic(index):
    q = queries_for(index, 2)

    def run():
        plan = FaultPlan({0: (FaultSpec("corrupt", mode="mix"),)}, seed=5)
        shard = plan.wrap(make_shards(index, 1))[0]
        return [shard(q, 5) for _ in range(len(CORRUPT_MODES))]

    for a, b in zip(run(), run()):
        np.testing.assert_array_equal(
            np.asarray(a.scores), np.asarray(b.scores))
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_chaos_plan_shape(index):
    with pytest.raises(ValueError):
        chaos_plan(2)
    plan = chaos_plan(4)
    wrapped = plan.wrap(make_shards(index, 4))
    assert len(wrapped) == 4
    assert [len(w.specs) for w in wrapped] == [2, 1, 1, 0]
    assert plan.calls() == [0, 0, 0, 0]


# --------------------------------------------------------- answer validation
def test_validate_answer_accepts_sentinels_and_short_rows():
    # a short answer from a tiny shard, with legal (-inf, -1) sentinel pads
    ans = ShardAnswer(np.array([[2.0, -np.inf], [1.0, 0.5]]),
                      np.array([[3, -1], [4, 0]]))
    validate_answer(ans, 2, 5, n_docs=10)


def test_validate_answer_rejects_each_corrupt_mode():
    # non-square on purpose: a transposed ("shape") answer must not alias
    clean = ShardAnswer(
        np.array([[2.0, 1.0, 0.5], [1.5, 0.5, 0.2]], np.float32),
        np.array([[3, 1, 5], [4, 0, 2]]))
    validate_answer(clean, 2, 3, n_docs=10)
    for mode in CORRUPT_MODES:
        with pytest.raises(AnswerValidationError):
            validate_answer(_corrupt(clean, mode, seed=0, call=0),
                            2, 3, n_docs=10)
    with pytest.raises(AnswerValidationError):                 # wrong rows
        validate_answer(clean, 3, 3, n_docs=10)
    with pytest.raises(AnswerValidationError):                 # float ids
        validate_answer(ShardAnswer(clean.scores,
                                    clean.ids.astype(np.float64)),
                        2, 3, n_docs=10)
    with pytest.raises(AnswerValidationError):   # -inf on a real id
        validate_answer(ShardAnswer(np.array([[-np.inf, 1.0]]),
                                    np.array([[3, 1]])), 1, 2, n_docs=10)


# ------------------------------------------------------------ circuit breaker
def test_circuit_breaker_state_machine():
    t = [0.0]
    seen = []
    br = CircuitBreaker(window=8, fail_rate=0.5, min_calls=4, cooldown_s=1.0,
                        clock=lambda: t[0],
                        on_transition=lambda old, new: seen.append((old, new)))
    br.record(False)
    br.record(False)
    assert br.state == "closed"                 # min_calls not met yet
    br.record(True)
    br.record(False)                            # 3/4 failed >= 0.5: trip
    assert br.state == "open" and br.opens == 1
    assert not br.allow() and not br.peek()
    br.record(False)                            # late result: ignored
    assert br.state == "open"
    t[0] = 1.0                                  # cooldown elapsed
    assert br.peek() and br.state == "open"     # peek never transitions
    assert br.allow() and br.state == "half_open"
    assert not br.allow() and not br.peek()     # single probe in flight
    br.record(False)                            # probe failed: re-open
    assert br.state == "open" and br.opens == 2
    t[0] = 2.0
    assert br.allow()
    br.record(True)                             # probe succeeded: close
    assert br.state == "closed" and br.closes == 1
    br.record(False)
    br.record(True)
    br.record(True)
    br.record(True)
    assert br.state == "closed"                 # window restarted clean
    assert ("closed", "open") in seen and ("half_open", "closed") in seen


# --------------------------------------------------------- router integration
def test_router_rejects_corrupt_answers_and_merge_stays_finite(index):
    plan = FaultPlan({1: (FaultSpec("corrupt", mode="nan"),)}, seed=1)
    with ShardedRouter(plan.wrap(make_shards(index, 3)), deadline_s=5.0,
                       n_docs=index.n_docs) as router:
        ans, degraded = router.search(queries_for(index, 4), 5)
        assert degraded
        assert not np.isnan(np.asarray(ans.scores)).any()
        assert (np.asarray(ans.ids) < index.n_docs).all()
        # initial call + its retry both rejected, never merged
        assert router.stats.rejected >= 2
        assert router.shard_health()[1]["rejected"] >= 2
        assert router.stats.failures >= 1


def test_router_retry_recovers_transient_fault(index):
    plan = FaultPlan({0: (FaultSpec("error", stop=1),)})
    with ShardedRouter(plan.wrap(make_shards(index, 2)), deadline_s=5.0,
                       backoff_base_s=0.001, n_docs=index.n_docs) as router:
        ans, degraded = router.search(queries_for(index, 2), 5)
        assert not degraded                     # retry healed inside the call
        assert router.stats.retries >= 1
        assert router.stats.failures == 0       # the search saw no failure
        validate_answer(ans, 2, 5, index.n_docs)


def test_router_breaker_opens_skips_and_recovers(index):
    plan = FaultPlan({0: (FaultSpec("error", stop=6),)})
    q = queries_for(index, 2)
    with ShardedRouter(plan.wrap(make_shards(index, 2)), deadline_s=5.0,
                       max_retries=1, backoff_base_s=0.001,
                       breaker_window=4, breaker_min_calls=2,
                       breaker_cooldown_s=0.05,
                       n_docs=index.n_docs) as router:
        for _ in range(4):                      # outage: breaker 0 trips
            ans, degraded = router.search(q, 5)
            assert degraded
            validate_answer(ans, 2, 5, index.n_docs)
        assert router.stats.breaker_opens >= 1
        assert router.stats.breaker_skips >= 1  # open shard skipped up front
        assert not router.backend_open          # shard 1 still serving
        time.sleep(0.06)                        # cooldown -> half-open probe
        deadline = time.monotonic() + 5.0
        while router.breakers[0].state != "closed":
            router.search(q, 5)
            time.sleep(0.06)
            assert time.monotonic() < deadline, "breaker never re-closed"
        assert router.stats.breaker_closes >= 1
        ans, degraded = router.search(q, 5)     # healthy again: full merge
        assert not degraded


def test_router_all_shards_failed_but_one_pads_sentinels(index):
    # shards 0+1 hard-down; the tiny survivor holds fewer docs than k, so
    # the degraded merge must sentinel-pad, never invent columns
    plan = FaultPlan({0: (FaultSpec("error"),), 1: (FaultSpec("error"),)})
    shards = make_shards(index, 3)
    lo = 2 * index.n_docs // 3                  # survivor's id range
    k = (index.n_docs - lo) + 3                 # k beyond the survivor
    with ShardedRouter(plan.wrap(shards), deadline_s=5.0, max_retries=0,
                       n_docs=index.n_docs) as router:
        ans, degraded = router.search(queries_for(index, 2), k)
        assert degraded
        ids, scores = np.asarray(ans.ids), np.asarray(ans.scores)
        assert ids.shape == (2, k)
        real = ids >= 0
        assert (ids[real] >= lo).all()          # only the survivor's docs
        assert np.isneginf(scores[~real]).all()  # sentinel-padded tail
        assert (~real).any()


def test_router_stats_lock_no_lost_updates(index):
    with ShardedRouter(make_shards(index, 2), deadline_s=10.0) as router:
        # raw counter hammering from many threads: totals must be exact
        def hammer():
            for _ in range(500):
                router.stats.bump("hedges")
                router.stats.shard_bump(0, "retries")
        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert router.stats.hedges == 8 * 500
        assert router.stats.per_shard[0]["retries"] == 8 * 500

        # concurrent searches (the scheduler overlaps backend waves): every
        # search and every per-shard call accounted, none lost
        q = queries_for(index, 2)
        errs = []

        def search_many():
            try:
                for _ in range(5):
                    router.search(q, 5)
            except Exception as e:              # pragma: no cover
                errs.append(e)
        threads = [threading.Thread(target=search_many) for _ in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs
        assert router.stats.calls == 6 * 5
        health = router.shard_health()
        assert sum(h["calls"] for h in health) == 2 * 6 * 5


def test_router_close_is_idempotent_and_context_managed(index):
    router = ShardedRouter(make_shards(index, 2), deadline_s=5.0)
    with router:
        ans, degraded = router.search(queries_for(index, 2), 5)
        assert not degraded
    router.close()                              # second close: no-op
    with pytest.raises(RuntimeError):           # pool is shut down
        router.search(queries_for(index, 2), 5)


# ------------------------------------------------------- degradation ladder
def _engine(index, docs, *, n_sessions=2, shared=None, router=None,
            backend="ref", validate_every=0, telemetry=None, epsilon=0.04,
            **router_kw):
    if router is None:
        # breaker cooldown defaults far out so a fenced back end STAYS
        # fenced for the duration of a test (recovery tests inject their
        # own clock); real serving uses sub-second cooldowns
        kw = dict(deadline_s=5.0, n_docs=index.n_docs,
                  breaker_window=4, breaker_min_calls=2,
                  breaker_cooldown_s=3600.0)
        kw.update(router_kw)
        router = ShardedRouter(make_shards(index, 2), **kw)
    return BatchedEngine(router, docs, dim=index.dim, n_sessions=n_sessions,
                         k=5, k_c=16, capacity=64, backend=backend,
                         shared=shared, validate_every=validate_every,
                         telemetry=telemetry, epsilon=epsilon)


def test_engine_shed_wave_serves_cache_without_router(index, docs):
    tel = ServeTelemetry()
    # epsilon far above any claim radius: every probe misses, so the wave
    # under a fenced back end must take the load-shed path
    eng = _engine(index, docs, telemetry=tel, epsilon=1e9)
    router = eng.router
    with router:
        for s in (0, 1):
            eng.start_session(s)
        q = queries_for(index, 2, seed=1)
        t_warm = eng.answer_batch([0, 1], list(q))
        assert all(isinstance(t, EngineTurn) for t in t_warm)
        for b in router.breakers:               # fence the whole back end
            for _ in range(2):
                b.record(False)
        assert router.backend_open

        def boom(*a, **k):                      # shed waves never search
            raise AssertionError("router.search called during shed wave")
        router.search = boom
        q2 = queries_for(index, 2, seed=2)
        before = int(np.asarray(eng.cache.state.n_queries).sum())
        turns = eng.answer_batch([0, 1], list(q2))
        for t in turns:
            assert isinstance(t, EngineTurn) and t.degraded
            assert t.ids.size and (t.ids >= 0).all()
        after = int(np.asarray(eng.cache.state.n_queries).sum())
        assert after == before                  # shed turns claim nothing
        assert tel.faults.get("shed_waves", 0) >= 1
        assert tel.faults.get("shed_turns", 0) >= 2
        assert tel.faults.get("degraded_turns", 0) >= 2


def test_engine_shed_then_breaker_recovery(index, docs):
    eng = _engine(index, docs, epsilon=1e9)
    router = eng.router
    # injected clock so the cooldown elapses exactly when the test says so
    # (wall-clock wave compiles would otherwise race a real cooldown)
    t = [0.0]
    router.breakers = [
        CircuitBreaker(window=4, fail_rate=0.5, min_calls=2,
                       cooldown_s=10.0, clock=lambda: t[0],
                       on_transition=router._transition_cb(i))
        for i in range(len(router.shards))]
    with router:
        for s in (0, 1):
            eng.start_session(s)
        q = queries_for(index, 2, seed=1)
        eng.answer_batch([0, 1], list(q))
        for b in router.breakers:
            for _ in range(2):
                b.record(False)
        assert router.backend_open
        turns = eng.answer_batch([0, 1], list(queries_for(index, 2, seed=2)))
        assert all(t.degraded for t in turns)
        t[0] = 11.0                             # cooldown: probes go out
        assert not router.backend_open
        turns = eng.answer_batch([0, 1], list(queries_for(index, 2, seed=3)))
        assert all(isinstance(t, EngineTurn) and not t.degraded
                   for t in turns)
        assert all(b.state == "closed" for b in router.breakers)
        assert router.stats.breaker_closes >= 2


def test_stale_memo_served_under_outage_never_records(index, docs):
    shared = SharedTier(dim=index.dim, n_shards=2, capacity=256,
                        memo_sim=0.9, ttl_waves=1)
    eng = _engine(index, docs, shared=shared)
    router = eng.router
    with router:
        for s in (0, 1):
            eng.start_session(s)
        q = queries_for(index, 2, seed=4)
        eng.answer_batch([0, 1], list(q))       # session 1 memoizes q[1]
        for _ in range(3):                      # TTL-expire the memo
            shared.tick()
        assert shared.memo_lookup(0, q[1]) is None      # fresh path: miss
        assert shared.memo_lookup(0, q[1], allow_stale=True) is not None
        for b in router.breakers:
            for _ in range(2):
                b.record(False)
        eng.start_session(0)                    # cold cache + fenced backend
        before = shared.n_promoted
        turns = eng.answer_batch([0], [q[1]])
        assert isinstance(turns[0], EngineTurn)
        assert turns[0].tier == "l2_reuse" and turns[0].degraded
        assert shared.n_stale_served >= 1
        assert shared.n_promoted == before      # stale serve claims nothing
        assert eng.telemetry.faults.get("stale_served", 0) >= 1


def test_engine_outage_with_cold_cache_still_fails(index, docs):
    eng = _engine(index, docs)
    with eng.router:
        for b in eng.router.breakers:
            for _ in range(2):
                b.record(False)
        eng.start_session(0)                    # no cache, no memo, no shards
        with pytest.raises(TimeoutError):
            eng.answer_batch([0], [queries_for(index, 1, seed=5)[0]])


# ------------------------------------------------------- cache-state guard
def test_validate_state_flags_each_corruption(index, docs):
    eng = _engine(index, docs, n_sessions=3)
    with eng.router:
        for s in range(3):
            eng.start_session(s)
        q = queries_for(index, 3, seed=6)
        eng.answer_batch([0, 1, 2], list(q))
        st = eng.cache.state
        cfg = eng.cache.cfg
        ok, problems = validate_state(st, cfg, n_corpus=index.n_docs)
        assert ok.all() and not problems

        bad = np.asarray(st.q_radius).copy()
        bad[0, 0] = np.nan                      # poisoned claim radius
        ok, problems = validate_state(st._replace(q_radius=jnp.asarray(bad)),
                                      cfg, n_corpus=index.n_docs)
        assert not ok[0] and ok[1] and ok[2]
        assert any("radius" in p for p in problems)

        bad = np.asarray(st.doc_ids).copy()
        bad[1, 0] = index.n_docs + 7            # out-of-corpus doc id
        ok, _ = validate_state(st._replace(doc_ids=jnp.asarray(bad)),
                               cfg, n_corpus=index.n_docs)
        assert not ok[1] and ok[0] and ok[2]

        bad = np.asarray(st.doc_emb).copy()
        bad[2, 0, 0] = np.inf                   # corrupted embedding payload
        ok, _ = validate_state(st._replace(doc_emb=jnp.asarray(bad)),
                               cfg, n_corpus=index.n_docs)
        assert not ok[2] and ok[0] and ok[1]

        bad = np.asarray(st.n_docs).copy()
        bad[0] = cfg.capacity + 1               # counter out of bounds
        ok, _ = validate_state(st._replace(n_docs=jnp.asarray(bad)),
                               cfg, n_corpus=index.n_docs)
        assert not ok[0]


def test_engine_quarantines_corrupt_slot_and_keeps_serving(index, docs):
    eng = _engine(index, docs, n_sessions=3, validate_every=1)
    with eng.router:
        for s in range(3):
            eng.start_session(s)
        q = queries_for(index, 3, seed=7)
        eng.answer_batch([0, 1, 2], list(q))
        st = eng.cache.state
        qr = np.asarray(st.q_radius).copy()
        qr[1, 0] = np.nan                       # bitrot in session 1's slot
        eng.cache.state = st._replace(q_radius=jnp.asarray(qr))
        # the next wave's integrity sweep quarantines + resets the slot and
        # the wave itself still answers every session
        turns = eng.answer_batch([0, 1, 2],
                                 list(queries_for(index, 3, seed=8)))
        assert all(isinstance(t, EngineTurn) for t in turns)
        assert eng.quarantined >= 1
        assert eng.telemetry.faults.get("quarantined_slots", 0) >= 1
        ok, _ = validate_state(eng.cache.state, eng.cache.cfg,
                               n_corpus=index.n_docs)
        assert ok.all()
        # the reset slot restarted from empty: its turn was a compulsory
        # back-end miss, not a hit on quarantined state
        assert turns[1].tier == "backend" and not turns[1].hit


def test_validate_state_scalar_unbatched_state(index):
    from repro.core.cache import CacheConfig, MetricCache
    cache = MetricCache(CacheConfig(capacity=32, dim=index.dim))
    ok, problems = validate_state(cache.state, cache.cfg)
    assert bool(ok) and not problems


# ------------------------------------------------------- launch contracts
def test_shed_wave_is_two_launches(index, docs, monkeypatch):
    """The load-shed wave keeps the outage launch contract: probe ->
    cache-fallback query, exactly 2 Pallas launches (claims never
    recorded, nothing inserted) — counted at trace time on the
    interpret tier, against a device-resident shard so the full-miss
    baseline shows its 3-launch shape first."""
    import jax.experimental.pallas as plmod

    from repro.dist.retrieval import DeviceShard

    dev = DeviceShard(jnp.asarray(docs),
                      jnp.arange(index.n_docs, dtype=jnp.int32),
                      backend="interpret")
    router = ShardedRouter([dev], deadline_s=120.0, n_docs=index.n_docs,
                           breaker_min_calls=2, breaker_cooldown_s=3600.0)
    eng = _engine(index, docs, backend="interpret", epsilon=1e9,
                  router=router)
    calls = {"n": 0}
    orig = plmod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(plmod, "pallas_call", counting)
    with router:
        for s in (0, 1):
            eng.start_session(s)
        q = queries_for(index, 2, seed=9)
        jax.clear_caches()
        calls["n"] = 0
        eng.answer_batch([0, 1], list(q))       # compulsory full-miss wave
        assert calls["n"] == 3, f"miss wave traced {calls['n']} launches"
        for b in router.breakers:
            for _ in range(2):
                b.record(False)
        assert router.backend_open
        jax.clear_caches()
        calls["n"] = 0
        turns = eng.answer_batch([0, 1], list(queries_for(index, 2,
                                                          seed=10)))
        assert calls["n"] == 2, f"shed wave traced {calls['n']} launches"
        assert all(t.degraded for t in turns)


@pytest.mark.slow
def test_scheduler_breaker_outage_recovery_interpret(index, docs):
    """Satellite: breaker-driven outage -> shed -> half-open recovery,
    driven through the continuous scheduler on the interpret tier — warm
    slots stay answerable (degraded) while the back end is fenced, and
    the first post-recovery wave is first-class again."""
    down = {"on": False}
    inner = make_shards(index, 2)

    def flaky(queries, k, j=0):
        if down["on"]:
            raise RuntimeError("shard down")
        return inner[j](queries, k)

    shards = [lambda q, k, j=j: flaky(q, k, j) for j in range(2)]
    router = ShardedRouter(shards, deadline_s=10.0, max_retries=1,
                           backoff_base_s=0.001, breaker_window=4,
                           breaker_min_calls=2, breaker_cooldown_s=0.2,
                           n_docs=index.n_docs)
    eng = BatchedEngine(router, docs, dim=index.dim, n_sessions=2, k=5,
                        k_c=16, capacity=64, backend="interpret")
    q = queries_for(index, 8, seed=11)
    with router, ContinuousScheduler(eng, window_s=60.0,
                                     adaptive=False) as sched:
        for s in (0, 1):
            eng.start_session(s)
        futs = [sched.submit(q[s], slot=s) for s in (0, 1)]
        assert all(isinstance(f.result(timeout=120), EngineTurn)
                   for f in futs)
        down["on"] = True                       # outage: breakers trip...
        futs = [sched.submit(q[2 + s], slot=s) for s in (0, 1)]
        t1 = [f.result(timeout=120) for f in futs]
        assert all(isinstance(t, EngineTurn) and t.degraded for t in t1)
        assert router.stats.breaker_opens >= 1
        futs = [sched.submit(q[4 + s], slot=s) for s in (0, 1)]
        t2 = [f.result(timeout=120) for f in futs]  # ...then waves shed
        assert all(isinstance(t, EngineTurn) and t.degraded for t in t2)
        down["on"] = False                      # recovery after cooldown
        time.sleep(0.25)
        deadline = time.monotonic() + 30.0
        while any(b.state != "closed" for b in router.breakers):
            futs = [sched.submit(q[6 + s], slot=s) for s in (0, 1)]
            [f.result(timeout=120) for f in futs]
            time.sleep(0.25)
            assert time.monotonic() < deadline, "breakers never re-closed"
        futs = [sched.submit(q[6 + s], slot=s) for s in (0, 1)]
        t3 = [f.result(timeout=120) for f in futs]
        assert all(isinstance(t, EngineTurn) and not t.degraded
                   for t in t3)
        assert router.stats.breaker_closes >= 1
