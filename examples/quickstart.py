"""Quickstart: the paper's CACHE in ~40 lines.

Builds a topical corpus, indexes it, runs one conversation through
Algorithm 1, and prints per-turn hit/miss + coverage.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core.conversation import ConversationalSearcher
from repro.core.metric_index import MetricIndex
from repro.data.conversations import WorldConfig, make_world


def main():
    world = make_world(WorldConfig(
        n_topics=8, docs_per_topic=800, n_background=4000, dim=256,
        subspace_dim=12, turns=8, n_conversations=1, doc_sigma=0.6,
        drift_sigma=0.16, subtopic_prob=0.35, subtopic_sigma=0.75, seed=0))
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))

    searcher = ConversationalSearcher(index=index, k=10, k_c=200,
                                      epsilon=0.04, measure_coverage=True)
    conv = world.conversations[0]
    queries = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))

    searcher.start_conversation()
    print(f"{'turn':>4} {'hit':>5} {'r_hat':>8} {'cov@10':>7} "
          f"{'cache docs':>10} {'top-1 doc':>10}")
    for t in range(conv.queries.shape[0]):
        rec = searcher.answer(queries[t])
        print(f"{t:>4} {str(rec.hit):>5} {rec.r_hat:8.3f} "
              f"{rec.coverage:7.2f} {rec.cache_docs:>10} {rec.ids[0]:>10}")
    print(f"\nhit rate (excl. compulsory first miss): "
          f"{100 * searcher.hit_rate():.1f}%")
    print(f"mean coverage vs exact search: {searcher.mean_coverage():.3f}")
    print(f"cache memory: {searcher.cache.memory_bytes() / 2**20:.1f} MiB")


if __name__ == "__main__":
    main()
