"""End-to-end serving driver: sharded back-end + hedging router + per-session
CACHE, with injected stragglers/failures to demonstrate the resilience path.

    PYTHONPATH=src python examples/conversational_serving.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.metric_index import MetricIndex
from repro.data.conversations import WorldConfig, make_world
from repro.serve.engine import ConversationalEngine
from repro.serve.router import ShardAnswer, ShardedRouter


def make_shards(index, n_shards, straggler=None):
    docs = np.asarray(index.doc_emb[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did, i=i):
            if i == straggler:
                time.sleep(0.8)          # simulated slow node
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def main():
    world = make_world(WorldConfig(
        n_topics=8, docs_per_topic=800, n_background=4000, dim=256,
        subspace_dim=12, turns=8, n_conversations=2, doc_sigma=0.6,
        drift_sigma=0.16, subtopic_prob=0.35, subtopic_sigma=0.75, seed=1))
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))

    router = ShardedRouter(make_shards(index, 8, straggler=3),
                           deadline_s=0.5, hedge_after_s=0.1)
    engine = ConversationalEngine(router, np.asarray(index.doc_emb),
                                  dim=index.dim, k=10, k_c=200)

    for ci, conv in enumerate(world.conversations):
        engine.start_session()
        qt = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))
        print(f"\n=== session {ci} (topic {conv.topic}) ===")
        for t in range(conv.queries.shape[0]):
            turn = engine.answer(np.asarray(qt[t]))
            print(f"turn {t}: hit={turn.hit} degraded={turn.degraded} "
                  f"latency={1e3 * turn.latency_s:7.1f} ms "
                  f"top1={turn.ids[0]}")
        print(f"session hit rate: {100 * engine.hit_rate():.0f}%  "
              f"router: hedges={router.stats.hedges} "
              f"degraded={router.stats.degraded}")


if __name__ == "__main__":
    main()
