"""End-to-end serving driver: sharded back-end + hedging router + per-session
CACHE, with injected stragglers/failures to demonstrate the resilience path —
then the same sessions served *concurrently* through the session-batched
engine (one batched probe / router round-trip / cache query per turn wave),
a topical-locality prefetch demo (offline k-means cluster index feeding
same-cluster neighbors into each miss's fused insert launch), and finally a
chaos replay: the committed deterministic fault schedule (flapping outage +
latency spikes + corrupt answers) served through the circuit-breaker /
validation / load-shed ladder.

    PYTHONPATH=src python examples/conversational_serving.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.metric_index import MetricIndex
from repro.core.shared import SharedTier
from repro.data.conversations import WorldConfig, make_world
from repro.serve.engine import ConversationalEngine
from repro.serve.faults import chaos_plan
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.session import BatchedEngine, SessionManager
from repro.serve.telemetry import ServeTelemetry


def make_shards(index, n_shards, straggler=None):
    docs = np.asarray(index.dequantized()[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    calls = {}
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did, i=i):
            calls[i] = calls.get(i, 0) + 1
            if i == straggler and calls[i] % 2 == 1:
                time.sleep(0.8)          # transient slow node: hedge rescues
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def main():
    world = make_world(WorldConfig(
        n_topics=8, docs_per_topic=800, n_background=4000, dim=256,
        subspace_dim=12, turns=8, n_conversations=2, doc_sigma=0.6,
        drift_sigma=0.16, subtopic_prob=0.35, subtopic_sigma=0.75, seed=1))
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32))

    with ShardedRouter(make_shards(index, 8, straggler=3),
                       deadline_s=0.5, hedge_after_s=0.1) as router:
        engine = ConversationalEngine(router, np.asarray(index.dequantized()),
                                      dim=index.dim, k=10, k_c=200)

        for ci, conv in enumerate(world.conversations):
            engine.start_session()
            qt = index.transform_queries(
                jnp.asarray(conv.queries, jnp.float32))
            print(f"\n=== session {ci} (topic {conv.topic}) ===")
            for t in range(conv.queries.shape[0]):
                turn = engine.answer(np.asarray(qt[t]))
                print(f"turn {t}: hit={turn.hit} degraded={turn.degraded} "
                      f"latency={1e3 * turn.latency_s:7.1f} ms "
                      f"top1={turn.ids[0]}")
            print(f"session hit rate: {100 * engine.hit_rate():.0f}%  "
                  f"router: hedges={router.stats.hedges} "
                  f"degraded={router.stats.degraded}")

    # ---- the same workload, batched across concurrent sessions ----------
    n_sessions = len(world.conversations)
    batched_router = ShardedRouter(make_shards(index, 8), deadline_s=5.0)
    batched = BatchedEngine(
        batched_router,
        np.asarray(index.dequantized()), dim=index.dim,
        n_sessions=n_sessions, k=10, k_c=200)
    mgr = SessionManager(batched)        # continuous slot-scheduled admission
    streams = [np.asarray(index.transform_queries(
        jnp.asarray(c.queries, jnp.float32))) for c in world.conversations]
    for s in range(n_sessions):
        mgr.open(s)
    print(f"\n=== batched: {n_sessions} concurrent sessions ===")
    t0 = time.perf_counter()
    for t in range(streams[0].shape[0]):
        futs = [mgr.submit(s, streams[s][t]) for s in range(n_sessions)]
        turns = [f.result(timeout=60) for f in futs]
        print(f"wave {t}: hits={sum(x.hit for x in turns)}/{n_sessions} "
              f"wave latency={1e3 * turns[0].latency_s:7.1f} ms")
    total = time.perf_counter() - t0
    rates = [100 * batched.hit_rate(s) for s in range(n_sessions)]
    print(f"throughput: {n_sessions * streams[0].shape[0] / total:.1f} q/s  "
          f"hit rates: {', '.join(f'{r:.0f}%' for r in rates)}")
    tel = mgr.telemetry.summary()
    tot, qw = tel["spans"]["total_s"], tel["spans"]["queue_wait_s"]
    print(f"SLO: p50={1e3 * tot['p50']:.1f} ms p99={1e3 * tot['p99']:.1f} ms "
          f"(queue wait p99={1e3 * qw['p99']:.1f} ms) over "
          f"{tel['waves']} waves, mean wave={tel['wave_size']['mean']:.1f}")
    mgr.shutdown()
    batched_router.close()

    # ---- topical-locality prefetch: k-means cluster index + warm fills --
    # A dedicated topical world (few dense topics in a low-dim subspace,
    # small query noise, norm_jitter=0 so the Eq. 1 coordinate stays flat)
    # where misses come from subtopic jumps — exactly the regime the
    # follow-up topical-locality paper targets.  The corpus is clustered
    # once offline; at each miss the engine folds up to `prefetch_width`
    # same-cluster neighbors into the one fused insert+query launch, so
    # the next subtopic jump lands on an already-warm cache.
    tw = make_world(WorldConfig(
        n_topics=4, docs_per_topic=300, n_background=600, dim=48,
        subspace_dim=4, turns=6, n_conversations=6, doc_sigma=0.8,
        query_sigma=0.05, drift_sigma=0.08, subtopic_prob=0.4,
        subtopic_sigma=0.45, norm_jitter=0.0, seed=11))
    tindex = MetricIndex(jnp.asarray(tw.doc_emb, jnp.float32))
    cluster = tindex.cluster(8, iters=10, seed=0, max_width=400,
                             backend="ref")
    n_sess = len(tw.conversations)
    tstreams = [np.asarray(tindex.transform_queries(
        jnp.asarray(c.queries, jnp.float32))) for c in tw.conversations]
    sids = list(range(n_sess))

    def replay(width):
        shared = SharedTier(dim=tindex.dim, n_shards=2, capacity=1024,
                            memo_sim=0.995,
                            cluster=cluster if width else None)
        with ShardedRouter(make_shards(tindex, 2), deadline_s=30) as rt:
            eng = BatchedEngine(rt,
                                np.asarray(tindex.dequantized()),
                                dim=tindex.dim,
                                n_sessions=n_sess, k=5, k_c=20, capacity=4096,
                                backend="ref", shared=shared,
                                cluster=cluster if width else None,
                                prefetch_width=width)
            for s in sids:
                eng.start_session(s)
            print(f"\n--- prefetch_width={width} ---")
            for t in range(tstreams[0].shape[0]):
                turns = eng.answer_batch(sids,
                                         [tstreams[s][t] for s in sids])
                tiers = " ".join(f"{x.tier:>7s}" for x in turns)
                warm = sum(x.prefetch_hits for x in turns)
                print(f"turn {t}: [{tiers}]  "
                      f"prefetch warm hits this wave={warm}")
            pf = eng.prefetch_stats()
            print(f"hit rate {100 * eng.hit_rate():.0f}%  "
                  f"tiers={eng.tier_counts()}"
                  f"  prefetch: issued={pf['issued']}"
                  f" warm_hits={pf['warm_hits']}"
                  f" insert_traffic={pf['insert_traffic_docs']} docs")
            return eng.hit_rate()

    print(f"\n=== topical prefetch: {n_sess} sessions, "
          f"{cluster.n_clusters} clusters over {tindex.n_docs} docs ===")
    base = replay(0)
    warm = replay(400)
    print(f"\nprefetch lifts combined hit rate "
          f"{100 * base:.0f}% -> {100 * warm:.0f}%")

    # ---- chaos replay: the committed fault schedule vs the ladder -------
    # chaos_plan is the exact schedule the CI chaos gate replays: shard 0
    # flaps through two full outage windows, shard 1 injects latency
    # spikes, shard 2 corrupts every other answer (NaN / inf / bad ids /
    # transposed), shard 3 stays healthy.  The router's breakers fence the
    # flapping shard, validation rejects every corrupt answer before the
    # merge, and warm sessions ride their caches through the outage.
    plan = chaos_plan(4, seed=23, spike_s=0.02)
    tel = ServeTelemetry()
    with ShardedRouter(plan.wrap(make_shards(tindex, 4)),
                       deadline_s=2.0, hedge_after_s=0.01, max_retries=1,
                       backoff_base_s=0.002, n_docs=tindex.n_docs,
                       breaker_window=8, breaker_min_calls=2,
                       breaker_cooldown_s=0.25, telemetry=tel) as rt:
        eng = BatchedEngine(rt, np.asarray(tindex.dequantized()),
                            dim=tindex.dim, n_sessions=n_sess, k=5, k_c=20,
                            capacity=4096, backend="ref", telemetry=tel)
        for s in sids:
            eng.start_session(s)
        print(f"\n=== chaos replay: {n_sess} sessions vs the committed "
              f"fault schedule ===")
        answered = total = 0
        for t in range(tstreams[0].shape[0]):
            try:
                turns = eng.answer_batch(sids,
                                         [tstreams[s][t] for s in sids])
            except TimeoutError:
                turns = [None] * n_sess
            ok = sum(x is not None for x in turns)
            answered, total = answered + ok, total + n_sess
            states = "".join(h["state"][0] for h in rt.shard_health())
            print(f"turn {t}: answered={ok}/{n_sess} breakers=[{states}] "
                  f"degraded={sum(bool(x and x.degraded) for x in turns)}")
        st = rt.stats
        print(f"availability {100 * answered / total:.0f}%  "
              f"rejected corrupt answers={st.rejected}  "
              f"breaker opens={st.breaker_opens} closes={st.breaker_closes} "
              f"skips={st.breaker_skips}  retries={st.retries} "
              f"hedges={st.hedges}  "
              f"injected faults={sum(w.faults for w in plan.wrapped)}")


if __name__ == "__main__":
    main()
