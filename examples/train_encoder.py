"""Train a ~100M-class encoder (the paper's STAR backbone shape) for a few
hundred steps on the synthetic Markov LM stream, with checkpointing and
restart — the end-to-end training driver.

    PYTHONPATH=src python examples/train_encoder.py [--steps 200]
"""

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.lm import LMBatchSpec, TokenStream
from repro.models import transformer as tf
from repro.train.optimizer import adamw
from repro.train.step import make_lm_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="artifacts/encoder_ckpt")
    ap.add_argument("--full-size", action="store_true",
                    help="use the real 12L/768d STAR shape (slow on CPU); "
                         "default is the reduced smoke config")
    args = ap.parse_args()

    mod = registry.get("star-encoder")
    cfg = mod.full_config() if args.full_size else mod.smoke_config()
    opt = adamw(lr=3e-4, warmup=20)
    step_fn = jax.jit(make_lm_train_step(cfg, opt, remat="none"))
    stream = TokenStream(LMBatchSpec(global_batch=16, seq_len=64,
                                     vocab_size=cfg.vocab_size))
    mgr = CheckpointManager(args.ckpt_dir, interval=50, keep=2)

    params = tf.init_params(jax.random.key(0), cfg)
    state, start = mgr.restore_or({"params": params, "opt": opt.init(params)})
    if start:
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, stream.batch(step))
        mgr.maybe_save(step + 1, state)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"({(time.time() - t0) / max(step - start + 1, 1):.2f}s/step)")
    mgr.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
