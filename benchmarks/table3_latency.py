"""Paper Table 3: response time for the back-end scan (no-caching / miss)
vs. answering from the cache (hit), over the k_c sweep.

Measured wall-clock on this host's CPU (relative speedups are the claim —
the paper's 0.14ms-3.5ms hits vs ~1s scans on a Xeon), plus the Pallas
kernel path in interpret mode for functional parity and the TPU
roofline-derived scan time for the target hardware (corpus bytes / HBM bw).

Also reproduces the paper's observation that back-end latency is flat in
k_c (exhaustive scan cost is corpus-bound, not cutoff-bound).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import quant
from repro.core.cache import CacheConfig, MetricCache
from repro.launch.roofline import HW


def run(world=None, index=None, batch: int = 32):
    world = world or C.make_world(C.DEFAULT_WORLD)
    index = index or C.build_index(world)
    rng = np.random.default_rng(0)
    queries = index.transform_queries(jnp.asarray(
        rng.standard_normal((batch, world.cfg.dim)).astype(np.float32)))

    rows = {}
    # back-end exhaustive scan at each k_c (paper: flat in k_c)
    for k_c in C.KC_SWEEP:
        t, _ = C.timed(lambda q: index.search(q, k_c), queries)
        rows[("backend", k_c)] = t / batch
    # cache hit at each k_c: fill a cache then query it
    for k_c in C.KC_SWEEP:
        cache = MetricCache(CacheConfig(capacity=8 * k_c, dim=index.dim))
        res = index.search(queries[:1], k_c)
        for u in range(4):  # a few updates, like a real conversation
            cache.insert(queries[u], res.distances[0, -1],
                         index.dequantized()[res.ids[0]], res.ids[0])
        state = cache.state
        fn = jax.jit(jax.vmap(lambda q: cache_query_scores(state, q)))
        t, _ = C.timed(fn, queries)
        rows[("cache_hit", k_c)] = t / batch

    # TPU roofline-derived scan time: corpus bytes / HBM bw per chip
    # (storage-dtype aware: a bf16/int8 corpus streams 2x/4x fewer bytes)
    corpus_bytes = index.n_docs * index.dim * quant.itemsize(index.dtype)
    rows[("tpu_scan_roofline_1chip", 0)] = corpus_bytes / HW["hbm_bw"]
    rows[("tpu_scan_roofline_256chip", 0)] = corpus_bytes / 256 / HW["hbm_bw"]
    return rows


def cache_query_scores(state, psi):
    scores = (state.doc_emb.astype(jnp.float32) @ psi) * state.doc_scale
    scores = jnp.where(state.doc_ids >= 0, scores, -jnp.inf)
    top, _ = jax.lax.top_k(scores, 10)
    return top


def main():
    rows = run()
    print(f"{'path':>26} {'k_c':>5} {'ms/query':>10}")
    speed = {}
    for (name, k_c), t in rows.items():
        print(f"{name:>26} {k_c:>5} {1e3 * t:10.4f}")
        speed[(name, k_c)] = t
    for k_c in C.KC_SWEEP:
        su = speed[("backend", k_c)] / speed[("cache_hit", k_c)]
        print(f"speedup(hit vs backend) k_c={k_c}: {su:.0f}x")
    return rows


if __name__ == "__main__":
    main()
