"""Paper Table 2 + Figs. 4/5: tuning epsilon from the r_hat vs coverage
correlation, then re-running dynamic-CACHE with the large-cutoff epsilon.

Reproduces the paper's methodology: on *train* conversations with
static-CACHE, find the r_hat threshold below which coverage@k <= 0.3, set
epsilon to it, and show that the larger epsilon recovers MAP@200 parity at
the cost of hit rate.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.metrics import ir


def tune_epsilon(world, index, k: int, k_c: int, frac_train: float = 0.4):
    """Fig 4/5 procedure -> (epsilon, correlation points)."""
    n_train = max(2, int(len(world.conversations) * frac_train))
    train = world.conversations[:n_train]
    pts = []                      # (r_hat, cov_k) per non-first turn
    from repro.core.conversation import ConversationalSearcher
    import jax.numpy as jnp
    s = ConversationalSearcher(index=index, k=k, k_c=k_c, policy="static")
    for conv in train:
        s.start_conversation()
        qt = index.transform_queries(jnp.asarray(conv.queries, jnp.float32))
        for t in range(conv.queries.shape[0]):
            rec = s.answer(qt[t])
            if t == 0:
                continue
            exact = index.search(qt[t][None], k)
            cov = ir.coverage(rec.ids.tolist(),
                              np.asarray(exact.ids[0]).tolist(), k)
            pts.append((rec.r_hat, cov))
    pts = np.asarray(pts)
    low = pts[pts[:, 1] <= 0.3]
    high = pts[pts[:, 1] > 0.7]
    # the "vertical line" of paper Fig. 4/5: the r_hat boundary separating
    # low-coverage from high-coverage queries (midpoint when both sides
    # exist; conservative high-side minimum otherwise)
    if low.size and high.size:
        eps = 0.5 * (float(low[:, 0].max()) + float(high[:, 0].min()))
    elif high.size:
        eps = float(high[:, 0].min())
    else:
        eps = 0.0
    return max(eps, 0.0), pts


def run(world=None, index=None):
    world = world or C.make_world(C.DEFAULT_WORLD)
    index = index or C.build_index(world)
    eval_convs = world.conversations
    base = C.evaluate_policy(world, index, "none", k_c=C.KC_SWEEP[0])

    # tune on the smallest cache cutoff (like the paper's k_c=1K of 38.6M):
    # larger cutoffs cover the whole topical cluster on this corpus and
    # leave no low-coverage points to calibrate against
    eps10, pts10 = tune_epsilon(world, index, k=10, k_c=C.KC_SWEEP[0])
    eps200, pts200 = tune_epsilon(world, index, k=200, k_c=C.KC_SWEEP[0])
    out = {"eps10": eps10, "eps200": eps200, "pts10": pts10, "pts200": pts200,
           "rows": []}
    for eps in sorted({eps10, eps200}):
        for k_c in C.KC_SWEEP:
            row = C.evaluate_policy(world, index, "dynamic", k_c=k_c,
                                    epsilon=eps, conversations=eval_convs)
            out["rows"].append(C.attach_significance(row, base))
    out["base"] = base
    return out


def main():
    out = run()
    print(f"tuned epsilon@10 = {out['eps10']:.4f}  "
          f"epsilon@200 = {out['eps200']:.4f} "
          f"(paper: 0.04 -> 0.07 analogue)")
    b = out["base"]
    print(f"{'eps':>6} {'k_c':>5} {'MAP@200':>8} {'nDCG@3':>7} {'hit%':>7} "
          f"{'p(MAP)':>7}   [no-caching MAP@200 {b.map200:.3f}]")
    for r in out["rows"]:
        print(f"{r.epsilon:6.3f} {r.k_c:>5} {r.map200:8.3f} {r.ndcg3:7.3f} "
              f"{100 * r.hit_rate:7.2f} {r.p_map:7.3f}")
    return out


if __name__ == "__main__":
    main()
