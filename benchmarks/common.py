"""Shared benchmark infrastructure: world building, CACHE evaluation sweeps,
significance testing (Welch t-test with normal-approx p; scipy unavailable)."""

from __future__ import annotations

import dataclasses
import math
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conversation import ConversationalSearcher
from repro.core.metric_index import MetricIndex
from repro.data.conversations import (TopicWorld, WorldConfig,
                                      make_world)  # noqa: F401  (re-exported: benchmarks use C.make_world)
from repro.metrics import ir

# Synthetic CAsT-like scale: the paper's k_c/corpus ratio (1K-10K of 38.6M)
# does not transfer to a 60K corpus, so k_c is swept over the same *relative*
# effect range (the cache holds one-to-several topical clusters).
DEFAULT_WORLD = WorldConfig(n_topics=16, docs_per_topic=2500,
                            n_background=12000, dim=768, turns=10,
                            n_conversations=12, doc_sigma=0.6,
                            query_sigma=0.12, drift_sigma=0.16,
                            subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
KC_SWEEP = (125, 250, 500, 1000)
K_EVAL = 200


def build_index(world: TopicWorld, use_kernel: bool | None = None) -> MetricIndex:
    """None follows the serving default: compiled kernel on TPU, jnp off it."""
    return MetricIndex(jnp.asarray(world.doc_emb, jnp.float32),
                       use_kernel=use_kernel)


@dataclasses.dataclass
class SweepRow:
    policy: str
    k_c: int
    epsilon: float
    map200: float
    mrr200: float
    ndcg3: float
    p1: float
    p3: float
    cov10: float
    hit_rate: float
    p_map: float       # Welch p-value vs no-caching per-query MAP
    p_ndcg: float
    max_cache_docs: int
    per_query: dict
    elapsed_s: float = 0.0   # wall clock of THIS row's sweep (per-policy)


def welch_p(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample Welch t-test, normal-approx p (n ~ hundreds)."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    va, vb = a.var(ddof=1) / len(a), b.var(ddof=1) / len(b)
    denom = math.sqrt(max(va + vb, 1e-30))
    t = (a.mean() - b.mean()) / denom
    return 2.0 * (1.0 - 0.5 * (1.0 + math.erf(abs(t) / math.sqrt(2.0))))


def evaluate_policy(world: TopicWorld, index: MetricIndex, policy: str,
                    k_c: int, epsilon: float = 0.04,
                    conversations=None) -> SweepRow:
    t_row = time.perf_counter()
    convs = conversations if conversations is not None else world.conversations
    per_q = {"map": [], "mrr": [], "ndcg": [], "p1": [], "p3": [],
             "cov10": [], "hit": [], "r_hat": []}
    max_docs = 0
    searcher = ConversationalSearcher(
        index=index, k=K_EVAL, k_c=k_c, epsilon=epsilon, policy=policy,
        cache_capacity=(len(convs[0].qrels) + 2) * k_c)
    for conv in convs:
        searcher.start_conversation()
        queries_t = index.transform_queries(
            jnp.asarray(conv.queries, jnp.float32))
        for t in range(conv.queries.shape[0]):
            rec = searcher.answer(queries_t[t])
            ranked = rec.ids.tolist()
            qr = conv.qrels[t]
            per_q["map"].append(ir.average_precision(ranked, qr, 200))
            per_q["mrr"].append(ir.mrr(ranked, qr, 200))
            per_q["ndcg"].append(ir.ndcg_at_k(ranked, qr, 3))
            per_q["p1"].append(ir.precision_at_k(ranked, qr, 1))
            per_q["p3"].append(ir.precision_at_k(ranked, qr, 3))
            if policy != "none":
                exact = index.search(queries_t[t][None], 10)
                per_q["cov10"].append(
                    ir.coverage(ranked, np.asarray(exact.ids[0]).tolist(), 10))
                if t > 0:
                    per_q["hit"].append(1.0 if rec.hit else 0.0)
                per_q["r_hat"].append(rec.r_hat)
        max_docs = max(max_docs, searcher.cache.n_docs)
    return SweepRow(
        policy=policy, k_c=k_c, epsilon=epsilon,
        map200=float(np.mean(per_q["map"])),
        mrr200=float(np.mean(per_q["mrr"])),
        ndcg3=float(np.mean(per_q["ndcg"])),
        p1=float(np.mean(per_q["p1"])),
        p3=float(np.mean(per_q["p3"])),
        cov10=float(np.mean(per_q["cov10"])) if per_q["cov10"] else float("nan"),
        hit_rate=float(np.mean(per_q["hit"])) if per_q["hit"] else float("nan"),
        p_map=float("nan"), p_ndcg=float("nan"),
        max_cache_docs=max_docs, per_query=per_q,
        elapsed_s=time.perf_counter() - t_row)


def attach_significance(row: SweepRow, base: SweepRow) -> SweepRow:
    row.p_map = welch_p(row.per_query["map"], base.per_query["map"])
    row.p_ndcg = welch_p(row.per_query["ndcg"], base.per_query["ndcg"])
    return row


def timed(fn, *args, n: int = 3, warmup: int = 1):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out
