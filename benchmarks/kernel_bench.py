"""Kernel-path benchmarks: dispatch-tier rows (ref / interpret / compiled)
for the pipelined fused kNN corpus scan and the session-batched cache
probe, plus the embedding bag — across the corpus storage dtypes (fp32 /
bf16 / int8, ``repro.core.quant``) and the native int8-MXU-dot tier
(``int8_dot``, int8 corpora only).

On a CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower — functional timing only, plus an equivalence gate); the
ref (jnp) rows are the CPU production paths.  Compiled rows appear only on
a real TPU backend.  TPU projections come from the roofline (corpus stream
bytes / HBM bandwidth) since the scan is bandwidth-bound — which is exactly
why the quantized dtypes matter: the ``knn_scan_bytes_*`` /
``knn_effective_bw_x_*`` rows report how many bytes one scan streams per
dtype and the resulting effective-bandwidth multiplier vs fp32 (bytes
shrink 2x / 4x, so a bandwidth-bound scan serves 2x / 4x the corpus per
second at the same HBM roofline), and the ``knn_roofline_frac_*`` rows
report the achieved fraction of that roofline per (tier, dtype) —
~meaningless on CPU hosts, the success metric for the double-buffered DMA
pipeline on real TPU hardware (a compiled fused scan that overlaps its
HBM copies with compute should approach 1.0).

Writes its row set under the ``"kernels"`` key of ``BENCH_retrieval.json``
(merge-update, so the retrieval rows written by ``retrieval_bench`` are
preserved).  ``--smoke`` runs tiny shapes and FAILS (non-zero exit) if

  * the interpret-mode kernels disagree with the ref tier in ranking at
    any dtype or under the int8-MXU dot (tiers must agree exactly at a
    fixed dtype + scoring rule), or
  * the quantized rankings drift below the documented rank-overlap floors
    vs the fp32 corpus (``RANK_OVERLAP_FLOOR`` — the int8-MXU tier gates
    at the established int8 floor), or
  * the int8 effective-bandwidth multiplier falls below 1.8x, or
  * any per-dtype effective-bandwidth multiplier regresses vs the
    committed ``BENCH_retrieval.json`` baseline (the pipelined scan must
    stream no more bytes than the scan it replaced)

— the CI regression gate for the kernel path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cache import CacheConfig, init_batched_cache, probe_batched
from repro.kernels import dispatch
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.knn.ops import knn_search
from repro.launch.roofline import HW

FULL = dict(n=65536, d=768, b=16, k=100, s=64, qmax=64)
SMOKE = dict(n=2048, d=128, b=4, k=10, s=8, qmax=16)

# Documented rank-equality tolerance of the quantized scan: mean top-k
# overlap vs the fp32 corpus must not fall below these floors (near-tied
# scores may legitimately swap order under quantization; the *set* of
# retrieved documents is the serving contract).  The native int8-MXU-dot
# tier ("int8dot": queries quantized too, int32-accumulated dot) gates at
# the established int8 floor.
RANK_OVERLAP_FLOOR = {"fp32": 1.0, "bf16": 0.95, "int8": 0.90,
                      "int8dot": 0.90}

# Acceptance floor for the int8 bandwidth win (ISSUE 4).
MIN_INT8_EFFECTIVE_BW_X = 1.8


def timed(fn, n: int = 3, warmup: int = 1):
    """Standalone copy of benchmarks.common.timed (this module must run as
    a plain script: ``python benchmarks/kernel_bench.py --smoke``)."""
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _scan_bytes(n: int, d: int, dtype: str) -> int:
    """HBM bytes one fused scan streams: corpus payload + int32 ids (+ f32
    per-document scales when the format carries them)."""
    per_doc = d * quant.itemsize(dtype) + 4
    if dtype == "int8":
        per_doc += 4
    return n * per_doc


def _rank_overlap(ids_a: np.ndarray, ids_b: np.ndarray) -> float:
    """Mean per-query top-k set overlap in [0, 1]."""
    k = ids_a.shape[1]
    return float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids_a, ids_b)]))


def _tier_rows(rows, label, tag, roofline_s, make_call, check):
    """Time one scoring config across the dispatch tiers; returns the ref
    output.  Emits per-tier wall-clock AND achieved-fraction-of-roofline
    (roofline_s / measured — the pipelined-scan success metric on TPU)."""
    t, ref_out = timed(make_call("ref"))
    rows[f"knn_ref_{label}_{tag}"] = t
    rows[f"knn_roofline_frac_ref_{label}_{tag}"] = roofline_s / t
    t, int_out = timed(make_call("interpret"), n=1, warmup=1)
    rows[f"knn_pallas_interpret_{label}_{tag}"] = t
    rows[f"knn_roofline_frac_interpret_{label}_{tag}"] = roofline_s / t
    if dispatch.on_tpu():
        t, comp_out = timed(make_call("compiled"))
        rows[f"knn_pallas_compiled_{label}_{tag}"] = t
        rows[f"knn_roofline_frac_compiled_{label}_{tag}"] = roofline_s / t
        if check:
            np.testing.assert_array_equal(np.asarray(comp_out[1]),
                                          np.asarray(ref_out[1]))
    if check:
        # tiers must agree EXACTLY in ranking at a fixed dtype + rule
        np.testing.assert_array_equal(np.asarray(int_out[1]),
                                      np.asarray(ref_out[1]))
        np.testing.assert_allclose(np.asarray(int_out[0]),
                                   np.asarray(ref_out[0]),
                                   rtol=2e-5, atol=2e-5)
    return ref_out


def _knn_rows(p, rows, check: bool):
    rng = np.random.default_rng(0)
    docs = jnp.asarray(_unit(rng, (p["n"], p["d"])))
    q = jnp.asarray(_unit(rng, (p["b"], p["d"])))
    ids = jnp.arange(p["n"], dtype=jnp.int32)
    tag = f"{p['n'] // 1024}k"
    k = p["k"]

    fp32_ids = None
    fp32_bytes = _scan_bytes(p["n"], p["d"], "fp32")
    quantized = {dt: quant.quantize(docs, dt) for dt in quant.DTYPES}
    # the int8-MXU-dot tier rides the int8 payload with a second scoring
    # rule — report it as its own pseudo-dtype row set ("int8dot")
    configs = [(dt, dt, False) for dt in quant.DTYPES]
    configs.append(("int8dot", "int8", True))
    for label, dt, i8dot in configs:
        qc = quantized[dt]

        def make_call(backend, qc=qc, i8dot=i8dot):
            return lambda: knn_search(
                docs=qc.data, doc_ids=ids, queries=q, k=k, backend=backend,
                scale=qc.scale, int8_dot=i8dot)

        scan_bytes = _scan_bytes(p["n"], p["d"], dt)
        roofline_s = scan_bytes / HW["hbm_bw"]
        ref_out = _tier_rows(rows, label, tag, roofline_s, make_call, check)
        rows[f"knn_scan_bytes_{label}_{tag}"] = float(scan_bytes)
        rows[f"knn_effective_bw_x_{label}_{tag}"] = fp32_bytes / scan_bytes
        rows[f"knn_tpu_roofline_{label}_{tag}"] = roofline_s
        if label == "fp32":
            fp32_ids = np.asarray(ref_out[1])
        overlap = _rank_overlap(np.asarray(ref_out[1]), fp32_ids)
        rows[f"knn_rank_overlap_vs_fp32_{label}_{tag}"] = overlap
        if check:
            floor = RANK_OVERLAP_FLOOR[label]
            assert overlap >= floor, (
                f"{label} top-{k} overlap vs fp32 = {overlap:.3f} < {floor}")
    # the A/B two-stage merge keeps parity at the widest and narrowest dtype
    t, _ = timed(lambda: knn_search(
        docs=docs, doc_ids=ids, queries=q, k=k, backend="interpret",
        two_stage=True, int8_dot=False), n=1, warmup=1)
    rows[f"knn_pallas_interpret_two_stage_fp32_{tag}"] = t
    if check:
        assert rows[f"knn_effective_bw_x_int8_{tag}"] >= \
            MIN_INT8_EFFECTIVE_BW_X, rows[f"knn_effective_bw_x_int8_{tag}"]


def _probe_rows(p, rows, check: bool):
    rng = np.random.default_rng(1)
    s, qmax, d = p["s"], p["qmax"], p["d"] + 1
    for dt in ("fp32", "int8"):
        cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax, store_dtype=dt)
        state = init_batched_cache(cfg, s)
        rec = quant.quantize(jnp.asarray(_unit(rng, (s, qmax, d))), dt)
        state = state._replace(
            q_emb=rec.data,
            q_scale=(state.q_scale if rec.scale is None else rec.scale),
            q_radius=jnp.asarray(
                rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
            # mixed fills: empty, partial, and ring-wrapped sessions
            n_queries=jnp.asarray(rng.integers(0, 2 * qmax, (s,)), jnp.int32))
        psi = jnp.asarray(_unit(rng, (s, d)))
        tag = f"{dt}_s{s}"

        t, ref_out = timed(lambda: probe_batched(state, psi, 0.04,
                                                 backend="ref"))
        rows[f"probe_ref_{tag}"] = t
        t, int_out = timed(lambda: probe_batched(state, psi, 0.04,
                                                 backend="interpret"),
                           n=1, warmup=1)
        rows[f"probe_pallas_interpret_{tag}"] = t
        if dispatch.on_tpu():
            t, comp_out = timed(lambda: probe_batched(state, psi, 0.04,
                                                      backend="compiled"))
            rows[f"probe_pallas_compiled_{tag}"] = t
            if check:
                np.testing.assert_array_equal(np.asarray(comp_out.nearest_q),
                                              np.asarray(ref_out.nearest_q))
        if check:
            np.testing.assert_array_equal(np.asarray(int_out.hit),
                                          np.asarray(ref_out.hit))
            np.testing.assert_array_equal(np.asarray(int_out.nearest_q),
                                          np.asarray(ref_out.nearest_q))


def _assert_no_bw_regression(rows: dict, baseline_path: str) -> None:
    """The pipelined fused scan must not regress effective bandwidth: every
    per-dtype ``knn_effective_bw_x_*`` row of the committed baseline must
    still exist and be matched or beaten (the multiplier is byte-count
    derived, so a regression means the scan started streaming MORE bytes
    per document than the scan it replaced)."""
    if not os.path.exists(baseline_path):
        return
    try:
        with open(baseline_path) as f:
            base = json.load(f).get("kernels_smoke", {}).get("metrics", {})
    except (json.JSONDecodeError, OSError):
        return
    for key, val in base.items():
        if not key.startswith("knn_effective_bw_x_"):
            continue
        assert key in rows, f"effective-bandwidth row disappeared: {key}"
        assert rows[key] >= val - 1e-9, (
            f"{key} regressed vs committed baseline: "
            f"{val:.3f} -> {rows[key]:.3f}")


def run(smoke: bool = False, out_path: str = "BENCH_retrieval.json",
        baseline_path: str = "BENCH_retrieval.json"):
    p = SMOKE if smoke else FULL
    rows: dict[str, float] = {}
    _knn_rows(p, rows, check=smoke)
    _probe_rows(p, rows, check=smoke)
    if smoke:
        _assert_no_bw_regression(rows, baseline_path)

    rng = np.random.default_rng(0)
    nbag = 4096 if not smoke else 256
    table = jnp.asarray(rng.standard_normal((100000, 64)).astype(np.float32))
    bag_idx = jnp.asarray(rng.integers(0, 100000, (nbag, 26)).astype(np.int32))
    t, _ = timed(lambda: embedding_bag(table, bag_idx, mode="sum"))
    rows[f"embedding_bag_jnp_{nbag}x26"] = t
    rows["embedding_bag_tpu_roofline"] = (nbag * 26 * 64 * 4) / HW["hbm_bw"]

    if out_path:
        key = "kernels_smoke" if smoke else "kernels"
        is_metric = lambda k: ("bytes" in k or "overlap" in k
                               or "bw_x" in k or "frac" in k)
        merge_json(out_path, {key: {
            "backend": dispatch.default_backend(),
            "dtype_default": quant.default_dtype(),
            "shapes": dict(p), "smoke": smoke,
            "rank_overlap_floor": dict(RANK_OVERLAP_FLOOR),
            "rows_us": {k: 1e6 * v for k, v in rows.items()
                        if not is_metric(k)},
            "metrics": {k: v for k, v in rows.items() if is_metric(k)},
            "timestamp": time.time(),
        }})
    return rows


def merge_json(path: str, updates: dict) -> None:
    """Merge ``updates`` into a JSON object file, preserving other keys
    (kernel_bench and retrieval_bench co-own BENCH_retrieval.json)."""
    rec = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                rec = json.load(f)
        except (json.JSONDecodeError, OSError):
            rec = {}
    if not isinstance(rec, dict):
        rec = {}
    rec.update(updates)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + ref/kernel equivalence gate")
    ap.add_argument("--out", default="BENCH_retrieval.json",
                    help="JSON path to merge the kernels row set into")
    ap.add_argument("--baseline", default="BENCH_retrieval.json",
                    help="committed baseline the smoke bandwidth gate "
                         "compares against")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, out_path=args.out,
               baseline_path=args.baseline)
    for k, v in rows.items():
        if "bytes" in k or "overlap" in k or "bw_x" in k or "frac" in k:
            print(f"{k:>52} {v:12.3g}")
        else:
            print(f"{k:>52} {1e3 * v:10.3f} ms")
    if args.smoke:
        print("kernel smoke: per-dtype tiers (incl. int8-MXU dot) agree; "
              "rank overlap and effective bandwidth above committed floors")
    return rows


if __name__ == "__main__":
    main()
