"""Kernel-path benchmarks: fused kNN (vs chunked jnp) and embedding bag.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower — functional timing only); the jnp paths are the CPU
production paths. TPU projections come from the roofline (corpus stream
bytes / HBM bandwidth) since the scan is bandwidth-bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.knn.ops import knn_search
from repro.launch.roofline import HW


def run():
    rng = np.random.default_rng(0)
    rows = {}
    docs = rng.standard_normal((65536, 768)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    q = rng.standard_normal((16, 768)).astype(np.float32)
    ids = jnp.arange(docs.shape[0], dtype=jnp.int32)
    docs_j, q_j = jnp.asarray(docs), jnp.asarray(q)

    from repro.core.metric_index import MetricIndex
    idx = MetricIndex(docs_j, chunk=8192)
    qt = idx.transform_queries(q_j)
    t, _ = C.timed(lambda: idx.search(qt, 100))
    rows["knn_jnp_chunked_64k"] = t
    t, _ = C.timed(lambda: knn_search(docs_j, ids, q_j, 100, interpret=True),
                   n=1, warmup=1)
    rows["knn_pallas_interpret_64k"] = t
    rows["knn_tpu_roofline_64k"] = docs.nbytes / HW["hbm_bw"]

    table = jnp.asarray(rng.standard_normal((100000, 64)).astype(np.float32))
    bag_idx = jnp.asarray(rng.integers(0, 100000, (4096, 26)).astype(np.int32))
    t, _ = C.timed(lambda: embedding_bag(table, bag_idx, mode="sum"))
    rows["embedding_bag_jnp_4096x26"] = t
    rows["embedding_bag_tpu_roofline"] = (4096 * 26 * 64 * 4) / HW["hbm_bw"]
    return rows


def main():
    rows = run()
    for k, v in rows.items():
        print(f"{k:>32} {1e3 * v:10.3f} ms")
    return rows


if __name__ == "__main__":
    main()
