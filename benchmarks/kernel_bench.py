"""Kernel-path benchmarks: dispatch-tier rows (ref / interpret / compiled)
for the fused kNN corpus scan and the session-batched cache probe, plus the
embedding bag.

On a CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower — functional timing only, plus an equivalence gate); the
ref (jnp) rows are the CPU production paths.  Compiled rows appear only on
a real TPU backend.  TPU projections come from the roofline (corpus stream
bytes / HBM bandwidth) since the scan is bandwidth-bound.

Writes its row set under the ``"kernels"`` key of ``BENCH_retrieval.json``
(merge-update, so the retrieval rows written by ``retrieval_bench`` are
preserved).  ``--smoke`` runs tiny shapes and FAILS (non-zero exit) if the
interpret-mode kernels disagree with the ref tier in ranking — the CI
regression gate for the kernel path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheConfig, init_batched_cache, probe_batched
from repro.core.metric_index import scan_topk
from repro.kernels import dispatch
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.knn.ops import knn_search
from repro.launch.roofline import HW

FULL = dict(n=65536, d=768, b=16, k=100, s=64, qmax=64)
SMOKE = dict(n=2048, d=128, b=4, k=10, s=8, qmax=16)


def timed(fn, n: int = 3, warmup: int = 1):
    """Standalone copy of benchmarks.common.timed (this module must run as
    a plain script: ``python benchmarks/kernel_bench.py --smoke``)."""
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out


def _unit(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _knn_rows(p, rows, check: bool):
    rng = np.random.default_rng(0)
    docs = jnp.asarray(_unit(rng, (p["n"], p["d"])))
    q = jnp.asarray(_unit(rng, (p["b"], p["d"])))
    ids = jnp.arange(p["n"], dtype=jnp.int32)
    tag = f"{p['n'] // 1024}k"
    k = p["k"]

    t, ref_out = timed(lambda: knn_search(docs, ids, q, k, backend="ref"))
    rows[f"knn_ref_{tag}"] = t
    t, _ = timed(lambda: scan_topk(docs, ids, q, k, chunk=min(8192, p["n"]),
                                     backend="ref"))
    rows[f"knn_chunked_{tag}"] = t
    t, int_out = timed(
        lambda: knn_search(docs, ids, q, k, backend="interpret"),
        n=1, warmup=1)
    rows[f"knn_pallas_interpret_{tag}"] = t
    t, _ = timed(
        lambda: knn_search(docs, ids, q, k, backend="interpret",
                           two_stage=True),
        n=1, warmup=1)
    rows[f"knn_pallas_interpret_two_stage_{tag}"] = t
    if dispatch.on_tpu():
        t, comp_out = timed(
            lambda: knn_search(docs, ids, q, k, backend="compiled"))
        rows[f"knn_pallas_compiled_{tag}"] = t
        if check:
            np.testing.assert_array_equal(np.asarray(comp_out[1]),
                                          np.asarray(ref_out[1]))
    rows[f"knn_tpu_roofline_{tag}"] = p["n"] * p["d"] * 4 / HW["hbm_bw"]
    if check:
        np.testing.assert_array_equal(np.asarray(int_out[1]),
                                      np.asarray(ref_out[1]))
        np.testing.assert_allclose(np.asarray(int_out[0]),
                                   np.asarray(ref_out[0]),
                                   rtol=2e-5, atol=2e-5)


def _probe_rows(p, rows, check: bool):
    rng = np.random.default_rng(1)
    s, qmax, d = p["s"], p["qmax"], p["d"] + 1
    cfg = CacheConfig(capacity=8, dim=d, max_queries=qmax)
    state = init_batched_cache(cfg, s)
    state = state._replace(
        q_emb=jnp.asarray(_unit(rng, (s, qmax, d))),
        q_radius=jnp.asarray(rng.uniform(0.2, 1.2, (s, qmax)).astype(np.float32)),
        # mixed fills: empty, partial, and ring-wrapped sessions
        n_queries=jnp.asarray(rng.integers(0, 2 * qmax, (s,)), jnp.int32))
    psi = jnp.asarray(_unit(rng, (s, d)))
    tag = f"s{s}"

    t, ref_out = timed(lambda: probe_batched(state, psi, 0.04,
                                               backend="ref"))
    rows[f"probe_ref_{tag}"] = t
    t, int_out = timed(lambda: probe_batched(state, psi, 0.04,
                                               backend="interpret"),
                         n=1, warmup=1)
    rows[f"probe_pallas_interpret_{tag}"] = t
    if dispatch.on_tpu():
        t, comp_out = timed(lambda: probe_batched(state, psi, 0.04,
                                                    backend="compiled"))
        rows[f"probe_pallas_compiled_{tag}"] = t
        if check:
            np.testing.assert_array_equal(np.asarray(comp_out.nearest_q),
                                          np.asarray(ref_out.nearest_q))
    if check:
        np.testing.assert_array_equal(np.asarray(int_out.hit),
                                      np.asarray(ref_out.hit))
        np.testing.assert_array_equal(np.asarray(int_out.nearest_q),
                                      np.asarray(ref_out.nearest_q))


def run(smoke: bool = False, out_path: str = "BENCH_retrieval.json"):
    p = SMOKE if smoke else FULL
    rows: dict[str, float] = {}
    _knn_rows(p, rows, check=smoke)
    _probe_rows(p, rows, check=smoke)

    rng = np.random.default_rng(0)
    nbag = 4096 if not smoke else 256
    table = jnp.asarray(rng.standard_normal((100000, 64)).astype(np.float32))
    bag_idx = jnp.asarray(rng.integers(0, 100000, (nbag, 26)).astype(np.int32))
    t, _ = timed(lambda: embedding_bag(table, bag_idx, mode="sum"))
    rows[f"embedding_bag_jnp_{nbag}x26"] = t
    rows["embedding_bag_tpu_roofline"] = (nbag * 26 * 64 * 4) / HW["hbm_bw"]

    if out_path:
        merge_json(out_path, {"kernels": {
            "backend": dispatch.default_backend(),
            "shapes": dict(p), "smoke": smoke,
            "rows_us": {k: 1e6 * v for k, v in rows.items()},
            "timestamp": time.time(),
        }})
    return rows


def merge_json(path: str, updates: dict) -> None:
    """Merge ``updates`` into a JSON object file, preserving other keys
    (kernel_bench and retrieval_bench co-own BENCH_retrieval.json)."""
    rec = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                rec = json.load(f)
        except (json.JSONDecodeError, OSError):
            rec = {}
    if not isinstance(rec, dict):
        rec = {}
    rec.update(updates)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + ref/kernel equivalence gate")
    ap.add_argument("--out", default="BENCH_retrieval.json",
                    help="JSON path to merge the kernels row set into")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, out_path=args.out)
    for k, v in rows.items():
        print(f"{k:>40} {1e3 * v:10.3f} ms")
    if args.smoke:
        print("kernel smoke: interpret-mode rankings match ref")
    return rows


if __name__ == "__main__":
    main()
