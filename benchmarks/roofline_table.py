"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import glob
import json
import os

OUT_DIR = "artifacts/dryrun"


def load(mesh: str = "16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, f"*@{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows, markdown: bool = False):
    lines = []
    sep = " | " if markdown else "  "
    hdr = sep.join([f"{'arch':<22}", f"{'shape':<14}", f"{'t_comp(s)':>10}",
                    f"{'t_mem(s)':>10}", f"{'t_coll(s)':>10}", f"{'dom':>5}",
                    f"{'useful':>7}", f"{'roofline%':>9}", f"{'HBM(GiB)':>9}"])
    lines.append(("| " + hdr + " |") if markdown else hdr)
    if markdown:
        lines.append("|" + "|".join(["---"] * 9) + "|")
    for r in rows:
        rl = r["roofline"]
        mem = r.get("memory", {}).get("total_hbm_bytes", 0) / 2 ** 30
        row = sep.join([
            f"{r['arch']:<22}", f"{r['shape']:<14}",
            f"{rl['t_compute_s']:>10.3e}", f"{rl['t_memory_s']:>10.3e}",
            f"{rl['t_collective_s']:>10.3e}", f"{rl['dominant'][:5]:>5}",
            f"{rl['useful_flops_ratio']:>7.3f}",
            f"{100 * rl['roofline_fraction']:>9.2f}", f"{mem:>9.2f}"])
        lines.append(("| " + row + " |") if markdown else row)
    return "\n".join(lines)


def main():
    rows = load()
    if not rows:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return []
    print(fmt_table(rows))
    return rows


if __name__ == "__main__":
    main()
