"""CI regression gate: compare a fresh smoke-bench JSON against the
committed baseline and fail (non-zero exit) on drift beyond the stated
tolerances.

Two comparisons, both against baselines committed in the repo:

  * serve:   /tmp/BENCH_serve_smoke.json   vs BENCH_serve.json["smoke"]
  * kernels: /tmp/BENCH_kernels_smoke.json["kernels_smoke"]
             vs BENCH_retrieval.json["kernels_smoke"]

Tolerances (CI hosts are noisy and heterogeneous, so quality metrics gate
hard while wall-clock gates are deliberately loose):

  * hit rates (the recall proxy of the serving smoke): absolute drift
    <= HIT_RATE_TOL vs baseline — a quantization or cache regression shows
    up here first.
  * batched-vs-sequential speedup: >= SPEEDUP_KEEP_FRAC of baseline — the
    batching win must not evaporate.
  * batched qps: >= QPS_KEEP_FRAC of baseline — absolute throughput may
    differ across machines, but an order-of-magnitude collapse is a bug.
  * kernel rank-overlap metrics: >= the floors recorded in the baseline
    (RANK_OVERLAP_FLOOR at bench time).
  * int8 effective scan bandwidth: >= MIN_INT8_BW_X (absolute — this is
    the ISSUE 4 acceptance floor, machine-independent by construction).
  * wave_moved_bytes (the zero-copy property of the pre-padded cache
    layout, jaxpr-derived so machine-independent): must exist, must stay
    <= MAX_WAVE_MOVED_FRAC of one stacked payload, and must not grow
    beyond WAVE_MOVED_GROWTH x the committed baseline.  Wave latency
    (best-of-N) gates loosely like the other wall-clock columns.
  * open-loop tail latency (the continuous-batching smoke): the continuous
    scheduler must beat the fixed-window baseline on p99 by at least
    OPEN_LOOP_P99_IMPROVEMENT_FLOOR, and the continuous p95/p99 columns
    must exist and stay within the loose wall-clock keep-fraction of the
    committed baseline.
  * topical prefetch (the cluster-prefetch Pareto sweep): the rows must
    include the width-0 tiered baseline plus wider settings with all
    traffic columns live, and hit_gap_best (best width > 0 hit rate minus
    width 0) must be STRICTLY positive — prefetch has to buy hit rate,
    never just traffic.

Usage (CI):
    python benchmarks/check_regression.py \
        --serve-current /tmp/BENCH_serve_smoke.json \
        --kernels-current /tmp/BENCH_kernels_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

HIT_RATE_TOL = 0.15
SPEEDUP_KEEP_FRAC = 0.3
QPS_KEEP_FRAC = 0.15
MIN_INT8_BW_X = 1.8
MAX_WAVE_MOVED_FRAC = 0.5   # non-launch traffic per wave vs ONE payload
WAVE_MOVED_GROWTH = 1.05    # jaxpr-derived, so near-exact across machines
WAVE_LATENCY_KEEP_FRAC = 0.15
# cache-hierarchy gates (Zipfian multi-user smoke; deterministic workload,
# so these are tight): the shared tier must serve a real share of traffic,
# the tiered hit rate must strictly beat private caches, back-end savings
# must not evaporate, and semantically reused result sets must stay
# rank-faithful to fresh retrieval
L2_HIT_RATE_FLOOR = 0.05
REUSE_OVERLAP_FLOOR = 0.95
BACKEND_SAVED_KEEP_FRAC = 0.7
# open-loop tail-latency gates (Poisson smoke, continuous scheduler vs the
# deprecated fixed-window admission): continuous must beat windowed on p99
# by at least the floor (the ISSUE-8 acceptance criterion; the measured
# margin is ~2x, so 1.1 tolerates shared-host noise), and the continuous
# p95/p99 may not collapse vs the committed baseline beyond the loose
# wall-clock keep-fraction the other latency columns use
OPEN_LOOP_P99_IMPROVEMENT_FLOOR = 1.1
OPEN_LOOP_LATENCY_KEEP_FRAC = 0.15
# chaos gates (the committed fault schedule of repro.serve.faults.
# chaos_plan replayed by serve_bench --chaos): the serving tier must stay
# answerable through flapping / latency-spiking / corrupting shards, no
# corrupt answer may ever reach a merged result, the flapping shard's
# breaker must both open and re-close within the run, the validator must
# actually reject the injected poison, and degraded answers must stay
# mostly rank-faithful to a clean fleet (a 4-shard merge missing one
# shard retains ~0.65 of the clean top-k; the floor tolerates one more
# skipped shard, not a garbage merge)
CHAOS_AVAILABILITY_FLOOR = 0.99
CHAOS_OVERLAP_FLOOR = 0.45


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_serve(current: dict, baseline: dict, errors: list) -> None:
    # serve_bench nests smoke records under "smoke" (full-run rows live at
    # the top level); accept either shape on both sides
    base = baseline.get("smoke", baseline)
    cur = current.get("smoke", current)
    if not base.get("rows") or not cur.get("rows"):
        errors.append("serve: missing rows in current or baseline record")
        return
    cur_row, base_row = cur["rows"][0], base["rows"][0]
    for key in ("hit_rate_sequential", "hit_rate_batched"):
        drift = abs(cur_row[key] - base_row[key])
        if drift > HIT_RATE_TOL:
            errors.append(
                f"serve: {key} drifted {drift:.3f} (> {HIT_RATE_TOL}): "
                f"{base_row[key]:.3f} -> {cur_row[key]:.3f}")
    floor = base_row["speedup"] * SPEEDUP_KEEP_FRAC
    if cur_row["speedup"] < floor:
        errors.append(
            f"serve: batched speedup {cur_row['speedup']:.2f}x below "
            f"{SPEEDUP_KEEP_FRAC:.0%} of baseline {base_row['speedup']:.2f}x")
    floor = base_row["batched_qps"] * QPS_KEEP_FRAC
    if cur_row["batched_qps"] < floor:
        errors.append(
            f"serve: batched qps {cur_row['batched_qps']:.1f} below "
            f"{QPS_KEEP_FRAC:.0%} of baseline {base_row['batched_qps']:.1f}")
    # zero-copy columns (pre-padded cache layout): their ABSENCE is itself
    # a failure — losing the columns would silently drop the gate
    for key in ("wave_moved_bytes", "wave_payload_bytes",
                "batched_wave_best_s"):
        if key not in cur_row:
            errors.append(f"serve: zero-copy column {key} missing from "
                          "current smoke record")
    if "wave_moved_bytes" in cur_row and "wave_payload_bytes" in cur_row:
        moved, payload = (cur_row["wave_moved_bytes"],
                          cur_row["wave_payload_bytes"])
        # absolute property: non-launch wave traffic well under one stacked
        # payload copy (the pre-padding layout moved >= 2x payload per wave)
        if moved > MAX_WAVE_MOVED_FRAC * payload:
            errors.append(
                f"serve: wave_moved_bytes {moved} exceeds "
                f"{MAX_WAVE_MOVED_FRAC:.0%} of the stacked payload "
                f"{payload} — a zero-copy regression")
        # relative: jaxpr-derived bytes are machine-independent, so any
        # growth beyond rounding is a real new copy on the hot path
        base_moved = base_row.get("wave_moved_bytes")
        if base_moved and moved > WAVE_MOVED_GROWTH * base_moved:
            errors.append(
                f"serve: wave_moved_bytes grew {base_moved} -> {moved} "
                f"(> {WAVE_MOVED_GROWTH}x baseline)")
    base_wave = base_row.get("batched_wave_best_s")
    cur_wave = cur_row.get("batched_wave_best_s")
    if base_wave and cur_wave and cur_wave > base_wave / WAVE_LATENCY_KEEP_FRAC:
        errors.append(
            f"serve: best wave latency {cur_wave * 1e3:.1f}ms beyond "
            f"{1 / WAVE_LATENCY_KEEP_FRAC:.1f}x baseline "
            f"{base_wave * 1e3:.1f}ms")
    _check_zipf(cur.get("zipf"), base.get("zipf") or {}, errors)
    _check_open_loop(cur.get("open_loop"), base.get("open_loop") or {},
                     errors)
    _check_prefetch(cur.get("prefetch"), base.get("prefetch") or {}, errors)
    _check_chaos(cur.get("chaos"), base.get("chaos") or {}, errors)


def _check_chaos(chaos, base_chaos: dict, errors: list) -> None:
    """Fault-resilience gates over the committed chaos-schedule record."""
    if not chaos:
        errors.append("serve: chaos record missing from current smoke "
                      "record — the fault-resilience gate lost its input")
        return
    for key in ("availability", "warm_availability", "corrupt_served",
                "breaker_opens", "breaker_closes", "rejected_answers",
                "degraded_turns", "degraded_overlap", "latency"):
        if key not in chaos:
            errors.append(f"serve: chaos column {key} missing")
    avail = chaos.get("warm_availability", 0.0)
    if avail < CHAOS_AVAILABILITY_FLOOR:
        errors.append(
            f"serve: warm-session availability under faults {avail:.4f} "
            f"below the {CHAOS_AVAILABILITY_FLOOR} floor")
    # the validator's whole job: poison NEVER reaches a merged answer
    if chaos.get("corrupt_served", 1):
        errors.append(
            f"serve: {chaos['corrupt_served']} corrupt answers were merged "
            "and served — answer validation failed open")
    # ... and it must have actually been exercised (the schedule injects
    # corrupt answers, so zero rejections means the injection or the
    # validation went dead, not that all was well)
    if not chaos.get("rejected_answers"):
        errors.append("serve: chaos run rejected no answers — the corrupt "
                      "shard or the validator is not firing")
    if not chaos.get("breaker_opens"):
        errors.append("serve: no circuit breaker opened under the flapping "
                      "shard — the breaker is not firing")
    if not chaos.get("breaker_closes"):
        errors.append("serve: no circuit breaker re-closed — half-open "
                      "recovery is not firing")
    # degraded answers must stay mostly right, not confidently wrong
    if not chaos.get("degraded_turns"):
        errors.append("serve: chaos run produced no degraded turns — the "
                      "degradation ladder is not being exercised")
    ovl = chaos.get("degraded_overlap")
    if ovl is not None:
        floor = max(CHAOS_OVERLAP_FLOOR,
                    (base_chaos.get("degraded_overlap") or 0.0)
                    - HIT_RATE_TOL)
        if ovl < floor:
            errors.append(
                f"serve: degraded-answer rank overlap {ovl:.3f} below "
                f"floor {floor:.3f}")
    if (chaos.get("latency") or {}).get("p99_ms") is None:
        errors.append("serve: chaos latency.p99_ms missing — no tail "
                      "measurement under faults")


def _check_prefetch(pf, base_pf: dict, errors: list) -> None:
    """Topical-locality prefetch gates over the Pareto sweep record."""
    if not pf:
        errors.append("serve: prefetch record missing from current smoke "
                      "record — the topical-prefetch gate lost its input")
        return
    rows = pf.get("rows") or []
    widths = [r.get("prefetch_width") for r in rows]
    if 0 not in widths or len(widths) < 2:
        errors.append("serve: prefetch sweep must include the width-0 tiered "
                      f"baseline plus at least one width > 0 (got {widths})")
        return
    for row in rows:
        for col in ("hit_rate", "backend_queries", "prefetch_issued",
                    "prefetch_warm_hits", "insert_traffic_docs",
                    "insert_traffic_bytes"):
            if col not in row:
                errors.append(f"serve: prefetch row width="
                              f"{row.get('prefetch_width')} misses {col}")
    # the acceptance headline: SOME width must strictly beat the width-0
    # tiered baseline on hit rate (the deterministic topical workload makes
    # this a hard gate, not a tolerance band)
    gap = pf.get("hit_gap_best")
    if gap is None:
        errors.append("serve: prefetch hit_gap_best column missing")
    elif gap <= 0.0:
        errors.append(
            f"serve: prefetch never beats the tiered baseline "
            f"(hit_gap_best {gap:+.3f} at width {pf.get('best_width')})")
    base_gap = base_pf.get("hit_gap_best")
    if base_gap and gap is not None and gap < base_gap - HIT_RATE_TOL:
        errors.append(
            f"serve: prefetch hit_gap_best regressed {base_gap:.3f} -> "
            f"{gap:.3f} (beyond the {HIT_RATE_TOL} tolerance)")
    # the Pareto trade must be charted honestly: the best width's warm hits
    # and traffic columns must be live (a zero here means attribution broke)
    best = next((r for r in rows
                 if r.get("prefetch_width") == pf.get("best_width")), None)
    if best is not None:
        if not best.get("prefetch_warm_hits"):
            errors.append("serve: best prefetch row records no warm hits")
        if not best.get("prefetch_issued"):
            errors.append("serve: best prefetch row issued no prefetches")


def _check_open_loop(ol, base_ol: dict, errors: list) -> None:
    """Tail-latency gates over the open-loop Poisson smoke record."""
    if not ol:
        errors.append("serve: open_loop record missing from current smoke "
                      "record — the tail-latency gate lost its input")
        return
    for mode in ("continuous", "windowed"):
        rec = ol.get(mode) or {}
        for col in ("p50_ms", "p95_ms", "p99_ms"):
            if (rec.get("total") or {}).get(col) is None:
                errors.append(f"serve: open_loop {mode} total.{col} missing")
        if (rec.get("queue_wait") or {}).get("p99_ms") is None:
            errors.append(f"serve: open_loop {mode} queue_wait.p99_ms "
                          "missing")
    imp = ol.get("p99_improvement")
    if imp is None:
        errors.append("serve: open_loop p99_improvement column missing")
    elif imp < OPEN_LOOP_P99_IMPROVEMENT_FLOOR:
        errors.append(
            f"serve: continuous scheduling beats the fixed window by only "
            f"{imp:.2f}x on p99 (< {OPEN_LOOP_P99_IMPROVEMENT_FLOOR}x "
            f"floor)")
    cur_total = (ol.get("continuous") or {}).get("total") or {}
    base_total = (base_ol.get("continuous") or {}).get("total") or {}
    for col in ("p95_ms", "p99_ms"):
        cur_v, base_v = cur_total.get(col), base_total.get(col)
        if cur_v and base_v and cur_v > base_v / OPEN_LOOP_LATENCY_KEEP_FRAC:
            errors.append(
                f"serve: open_loop continuous {col} {cur_v:.1f}ms beyond "
                f"{1 / OPEN_LOOP_LATENCY_KEEP_FRAC:.1f}x baseline "
                f"{base_v:.1f}ms")


def _check_zipf(zipf, base_zipf: dict, errors: list) -> None:
    """Cache-hierarchy gates over the Zipfian multi-user smoke record."""
    if not zipf:
        errors.append("serve: zipf record missing from current smoke "
                      "record — the cache-hierarchy gate lost its input")
        return
    for key in ("hit_rate", "l1_hit_rate", "l2_hit_rate",
                "l1_only_hit_rate", "hit_gap", "backend_queries_saved",
                "reuse_overlap", "n_reuse_sampled"):
        if key not in zipf:
            errors.append(f"serve: zipf column {key} missing")
    # the tier's raison d'etre: combined L1+L2 strictly beats private-only
    if zipf.get("hit_gap", 0.0) <= 0.0:
        errors.append(
            f"serve: tiered hit rate {zipf.get('hit_rate')} does not beat "
            f"the L1-only baseline {zipf.get('l1_only_hit_rate')}")
    l2_floor = max(L2_HIT_RATE_FLOOR,
                   base_zipf.get("l2_hit_rate", 0.0) - HIT_RATE_TOL)
    if zipf.get("l2_hit_rate", 0.0) < l2_floor:
        errors.append(
            f"serve: l2_hit_rate {zipf.get('l2_hit_rate')} below floor "
            f"{l2_floor:.3f}")
    saved = zipf.get("backend_queries_saved", 0)
    base_saved = base_zipf.get("backend_queries_saved")
    if saved <= 0:
        errors.append("serve: shared tier saved no backend queries")
    elif base_saved and saved < BACKEND_SAVED_KEEP_FRAC * base_saved:
        errors.append(
            f"serve: backend_queries_saved regressed {base_saved} -> "
            f"{saved} (< {BACKEND_SAVED_KEEP_FRAC:.0%} of baseline)")
    # reused result sets must stay rank-faithful to fresh retrieval; a
    # smoke run in which reuse never happens is itself a regression (the
    # workload is seeded, so reuse is deterministic)
    if not zipf.get("n_reuse_sampled"):
        errors.append("serve: no semantic result reuse occurred in the "
                      "zipf smoke workload")
    elif (zipf.get("reuse_overlap") is not None
          and zipf["reuse_overlap"] < REUSE_OVERLAP_FLOOR):
        errors.append(
            f"serve: reuse_overlap {zipf['reuse_overlap']:.3f} below the "
            f"{REUSE_OVERLAP_FLOOR} quality floor")


def check_kernels(current: dict, baseline: dict, errors: list) -> None:
    cur = current.get("kernels_smoke", current.get("kernels"))
    base = baseline.get("kernels_smoke", baseline.get("kernels"))
    if not cur or not base:
        errors.append("kernels: missing kernels_smoke record")
        return
    cur_m = cur.get("metrics", {})
    floors = base.get("rank_overlap_floor", {})
    for key, val in cur_m.items():
        if "rank_overlap_vs_fp32" in key:
            dt = key.split("rank_overlap_vs_fp32_")[1].split("_")[0]
            floor = floors.get(dt)
            if floor is not None and val < floor:
                errors.append(
                    f"kernels: {key} = {val:.3f} below floor {floor}")
    int8_bw = [v for k, v in cur_m.items()
               if k.startswith("knn_effective_bw_x_int8")]
    if not int8_bw:
        errors.append("kernels: no int8 effective-bandwidth row in current")
    elif min(int8_bw) < MIN_INT8_BW_X:
        errors.append(
            f"kernels: int8 effective scan bandwidth {min(int8_bw):.2f}x "
            f"below the {MIN_INT8_BW_X}x acceptance floor")
    # the pipelined scan must match-or-beat every per-dtype effective
    # bandwidth the baseline recorded (byte-count derived: a drop means
    # the scan streams more HBM bytes per document than it used to)
    for key, val in base.get("metrics", {}).items():
        if key.startswith("knn_effective_bw_x_") and key in cur_m:
            if cur_m[key] < val - 1e-9:
                errors.append(
                    f"kernels: {key} regressed {val:.3f} -> "
                    f"{cur_m[key]:.3f}")
    # the achieved-fraction-of-roofline columns are the pipelined-scan
    # wiring's fingerprint — their absence means the bench lost them
    if not any("roofline_frac" in k for k in cur_m):
        errors.append("kernels: no roofline-fraction rows in current")
    # quantized rows must still exist for every dtype the baseline had
    missing = [k for k in base.get("metrics", {}) if k not in cur_m]
    if missing:
        errors.append(f"kernels: metrics disappeared vs baseline: {missing}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serve-current", default="/tmp/BENCH_serve_smoke.json")
    ap.add_argument("--serve-baseline", default="BENCH_serve.json")
    ap.add_argument("--kernels-current",
                    default="/tmp/BENCH_kernels_smoke.json")
    ap.add_argument("--kernels-baseline", default="BENCH_retrieval.json")
    ap.add_argument("--chaos-only", action="store_true",
                    help="gate only the chaos (fault-resilience) record of "
                         "the serve smoke — the fast CI chaos job")
    args = ap.parse_args()

    errors: list[str] = []
    if args.chaos_only:
        current = _load(args.serve_current)
        baseline = _load(args.serve_baseline)
        cur = current.get("smoke", current)
        base = baseline.get("smoke", baseline)
        _check_chaos(cur.get("chaos"), base.get("chaos") or {}, errors)
    else:
        check_serve(_load(args.serve_current), _load(args.serve_baseline),
                    errors)
        check_kernels(_load(args.kernels_current),
                      _load(args.kernels_baseline), errors)
    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print("check_regression: smoke benches within tolerance of committed "
          "baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
