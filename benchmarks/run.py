"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
``derived`` carries each table's headline quality/efficiency number.

The sharded-retrieval rows need a multi-device topology; they run in a
subprocess (``benchmarks.retrieval_bench``) so this process's
single-device timing baseline for tables 1-3 and the kernel rows stays
comparable across PRs.
"""

from __future__ import annotations

import os
import sys
import time


def _csv(name, us, derived):
    print(f"{name},{us:.3f},{derived}")


def main() -> None:
    from benchmarks import common as C

    t0 = time.perf_counter()
    world = C.make_world(C.DEFAULT_WORLD)
    index = C.build_index(world)
    _csv("world_build", 1e6 * (time.perf_counter() - t0), f"docs={world.n_docs}")

    # --- Table 1: effectiveness + hit rate -------------------------------
    # every row reports ITS OWN elapsed wall clock (rows used to share one
    # whole-table average, which flattened per-policy timing trajectories)
    from benchmarks import table1_effectiveness
    rows = table1_effectiveness.run(world, index)
    base = rows[0]
    _csv("table1_no_caching", 1e6 * base.elapsed_s,
         f"MAP200={base.map200:.3f};nDCG3={base.ndcg3:.3f}")
    for r in rows[1:]:
        _csv(f"table1_{r.policy}_kc{r.k_c}", 1e6 * r.elapsed_s,
             f"MAP200={r.map200:.3f};nDCG3={r.ndcg3:.3f};cov10={r.cov10:.2f};"
             f"hit={100 * r.hit_rate:.1f}%;p_ndcg={r.p_ndcg:.3f}")

    # --- Table 2 / Fig 4-5: epsilon tuning --------------------------------
    from benchmarks import table2_epsilon
    t0 = time.perf_counter()
    out = table2_epsilon.run(world, index)
    dt = 1e6 * (time.perf_counter() - t0)
    _csv("table2_eps_tuned", dt, f"eps10={out['eps10']:.4f};"
                                 f"eps200={out['eps200']:.4f}")
    for r in out["rows"]:
        _csv(f"table2_dynamic_eps{r.epsilon:.3f}_kc{r.k_c}",
             1e6 * r.elapsed_s,
             f"MAP200={r.map200:.3f};hit={100 * r.hit_rate:.1f}%;"
             f"p_map={r.p_map:.3f}")

    # --- Table 3: latency --------------------------------------------------
    from benchmarks import table3_latency
    rows3 = table3_latency.run(world, index)
    for (name, k_c), t in rows3.items():
        _csv(f"table3_{name}_kc{k_c}", 1e6 * t, f"ms={1e3 * t:.4f}")
    kc_top = C.KC_SWEEP[-1]
    hit = rows3[("cache_hit", kc_top)]
    back = rows3[("backend", kc_top)]
    _csv(f"table3_speedup_kc{kc_top}", 1e6 * hit,
         f"speedup={back / hit:.0f}x")

    # --- kernels ------------------------------------------------------------
    from benchmarks import kernel_bench
    rowsk = kernel_bench.run()
    for name, t in rowsk.items():
        _csv(f"kernel_{name}", 1e6 * t, f"ms={1e3 * t:.3f}")

    # --- distributed retrieval: exact vs chunked vs sharded @ 1M docs -------
    # own subprocess: it forces an 8-device topology, which must not leak
    # into this process's timings
    import json
    import subprocess
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.retrieval_bench"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode:
        print(f"retrieval bench failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
    else:
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        for name in ("exact", "chunked", "sharded"):
            _csv(f"retrieval_{name}_1M", rec[f"{name}_us"],
                 f"ndev={rec['n_devices']};"
                 f"identical={rec['rankings_identical']}")
        _csv("retrieval_sharded_speedup", rec["sharded_us"],
             f"vs_chunked={rec['sharded_speedup_vs_chunked']:.2f}x")

    # --- batched multi-session serving (sequential loop vs waves) -----------
    from benchmarks import serve_bench
    rec_s = serve_bench.run((64,), repeats=1,
                            out_path="BENCH_serve_row.json")
    for row in rec_s["rows"]:
        _csv(f"serve_batched_s{row['sessions']}",
             1e6 * row["batched_s"] / max(row["queries"], 1),
             f"qps={row['batched_qps']:.1f};"
             f"vs_sequential={row['speedup']:.2f}x;"
             f"hit={100 * row['hit_rate_batched']:.1f}%")

    # --- roofline table (from dry-run artifacts, if present) ----------------
    from benchmarks import roofline_table
    rows_r = roofline_table.load()
    for r in rows_r:
        rl = r["roofline"]
        _csv(f"roofline_{r['arch']}@{r['shape']}", 0.0,
             f"dom={rl['dominant']};frac={rl['roofline_fraction']:.4f}")
    print("benchmarks complete", file=sys.stderr)


if __name__ == "__main__":
    main()
