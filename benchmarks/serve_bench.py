"""Multi-session serving benchmark: sequential engine loop vs batched serving.

Replays the same per-session turn streams two ways —

  * **sequential**: one ``ConversationalEngine`` per session, answered one
    turn at a time (the paper's client model: one probe, one router
    round-trip, one cache query per turn), and
  * **batched**: one ``BatchedEngine`` answering each turn wave with one
    batched probe, one ``router.search`` over the whole miss subset, and one
    batched insert/query

— and reports wall-clock queries/sec for each at several concurrency
levels.  Writes ``BENCH_serve.json``.

The closed-loop run also sweeps the topical-locality prefetch path
(``bench_prefetch``): the same conversations replayed at several
``prefetch_width`` settings over a clustered corpus, emitting hit-rate
vs cache-traffic Pareto rows with width 0 as the pre-prefetch tiered
baseline (the gap is gated by ``check_regression.py``).

``--open-loop`` instead drives the asynchronous front door with an
open-loop Poisson arrival process (arrivals do NOT wait for previous
turns — the honest way to measure tail latency) plus session churn, twice:
once through the continuous scheduler and once through the deprecated
fixed-window admission, reporting per-turn p50/p95/p99 (total and queue
wait, per serving tier) and the continuous-vs-windowed p99 improvement
``check_regression.py`` gates.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--open-loop]

``--chaos`` replays the COMMITTED fault schedule (``repro.serve.faults.
chaos_plan``: one flapping shard, one latency-spiking shard, one shard
returning corrupt answers) against the full resilient serving stack and
emits the ``chaos`` record ``check_regression.py`` gates: warm-session
availability, zero corrupt answers merged, breaker open/re-close counts,
degraded-answer rank overlap vs a clean fleet, and tail latency under
faults.

``--smoke`` runs a seconds-scale configuration (CI exercises the batched
path on every push); the default sweep covers 64-512 concurrent sessions.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cache import (CacheConfig, init_batched_cache,
                              insert_query_batched, probe_batched)
from repro.core.metric_index import MetricIndex
from repro.core.shared import SharedTier
from repro.kernels import jaxpr_util
from repro.data.conversations import WorldConfig, make_world
from repro.serve.engine import ConversationalEngine
from repro.serve.faults import chaos_plan
from repro.serve.router import ShardAnswer, ShardedRouter
from repro.serve.session import BatchedEngine, SessionManager
from repro.serve.telemetry import ServeTelemetry


def make_shards(index: MetricIndex, n_shards: int):
    docs = np.asarray(index.dequantized()[:index.n_docs])
    ids = np.arange(index.n_docs)
    bounds = np.linspace(0, index.n_docs, n_shards + 1).astype(int)
    shards = []
    for i in range(n_shards):
        d, did = docs[bounds[i]:bounds[i + 1]], ids[bounds[i]:bounds[i + 1]]

        def shard(queries, k, d=d, did=did):
            scores = queries @ d.T
            top = np.argsort(-scores, axis=1)[:, :k]
            return ShardAnswer(np.take_along_axis(scores, top, axis=1),
                               did[top])
        shards.append(shard)
    return shards


def _streams(world, index, n_sessions: int):
    """Per-session transformed query streams (conversations reused round-
    robin when sessions outnumber generated conversations)."""
    convs = world.conversations
    return [np.asarray(index.transform_queries(
        jnp.asarray(convs[s % len(convs)].queries, jnp.float32)))
        for s in range(n_sessions)]


def bench_sequential(index, streams, *, n_shards, k, k_c, capacity,
                     dtype=None):
    with ShardedRouter(make_shards(index, n_shards),
                       deadline_s=30) as router:
        doc = np.asarray(index.dequantized())
        engines = [ConversationalEngine(router, doc, dim=index.dim, k=k,
                                        k_c=k_c, capacity=capacity,
                                        dtype=dtype)
                   for _ in streams]
        for e in engines:
            e.start_session()
        turns = streams[0].shape[0]
        t0 = time.perf_counter()
        for t in range(turns):
            for s, e in enumerate(engines):
                e.answer(streams[s][t])
        elapsed = time.perf_counter() - t0
        hits = float(np.mean([e.hit_rate() for e in engines]))
        return elapsed, len(streams) * turns, hits


def _rank_overlap(ids_a, ids_b, k: int) -> float:
    """Top-k set overlap in [0, 1] for one result pair (the per-query core
    of benchmarks.kernel_bench._rank_overlap, standalone for script use)."""
    return len(set(np.asarray(ids_a)[:k].tolist())
               & set(np.asarray(ids_b)[:k].tolist())) / k


def bench_zipf(index, world, *, n_sessions, n_generations=3, alpha=1.1,
               jitter=0.005, n_shards=4, k=10, k_c=100, capacity=None,
               dtype=None, with_shared=True, seed=11):
    """Popularity-skewed multi-user workload: the global-vs-private gap.

    ``n_generations`` cohorts of ``n_sessions`` sessions each run a full
    conversation; every session draws its conversation from a Zipf(alpha)
    popularity distribution over the world's conversation pool, with
    per-session query jitter (so cross-session repeats are near-duplicate,
    never identical — the semantic-reuse case, not trivial memoization).
    Between generations every session restarts with an empty L1 cache: a
    new user asking a popular question is exactly where a private cache
    pays a compulsory miss and the shared tier does not.

    Returns hit-rate accounting over ALL turns (compulsory first turns
    included — they are the point), per-tier counts, back-end query count,
    and the rank overlap of semantically reused result sets vs fresh
    retrieval (the quality gate for the memo's similarity floor).
    """
    router = ShardedRouter(make_shards(index, n_shards), deadline_s=30)
    try:
        return _bench_zipf_body(router, index, world,
                                n_sessions=n_sessions,
                                n_generations=n_generations, alpha=alpha,
                                jitter=jitter, n_shards=n_shards, k=k,
                                k_c=k_c, capacity=capacity, dtype=dtype,
                                with_shared=with_shared, seed=seed)
    finally:
        router.close()


def _bench_zipf_body(router, index, world, *, n_sessions, n_generations,
                     alpha, jitter, n_shards, k, k_c, capacity, dtype,
                     with_shared, seed):
    shared = SharedTier(dim=index.dim, n_shards=n_shards,
                        capacity=max(8 * k_c, 1024), memo_sim=0.995,
                        dtype=dtype) if with_shared else None
    engine = BatchedEngine(router, np.asarray(index.dequantized()),
                           dim=index.dim, n_sessions=n_sessions, k=k,
                           k_c=k_c, capacity=capacity or 4 * k_c,
                           dtype=dtype, shared=shared)
    rng = np.random.default_rng(seed)
    convs = world.conversations
    pop = np.arange(1, len(convs) + 1, dtype=np.float64) ** -alpha
    pop /= pop.sum()
    sids = list(range(n_sessions))
    counts = {"l1": 0, "l2": 0, "l2_reuse": 0, "backend": 0}
    reuse_samples: list = []
    t0 = time.perf_counter()
    for _g in range(n_generations):
        choice = rng.choice(len(convs), size=n_sessions, p=pop)
        for s in sids:
            engine.start_session(s)
        streams = []
        for s in sids:
            raw = (np.asarray(convs[choice[s]].queries)
                   + jitter * rng.standard_normal(
                       convs[choice[s]].queries.shape))
            streams.append(np.asarray(index.transform_queries(
                jnp.asarray(raw, jnp.float32))))
        for t in range(streams[0].shape[0]):
            qs = [streams[s][t] for s in sids]
            turns = engine.answer_batch(sids, qs)
            for s, turn in zip(sids, turns):
                counts[turn.tier] += 1
                if turn.tier == "l2_reuse" and len(reuse_samples) < 32:
                    reuse_samples.append((qs[s], np.asarray(turn.ids)))
    elapsed = time.perf_counter() - t0
    total = sum(counts.values())
    # quality of reused result sets: top-k overlap vs a fresh retrieval of
    # the SAME query (the gated floor backing the memo_sim calibration)
    overlaps = []
    for psi_q, served_ids in reuse_samples:
        ans, _ = router.search(np.asarray(psi_q)[None], k_c)
        fresh = ans.ids[0][ans.ids[0] >= 0]
        overlaps.append(_rank_overlap(served_ids, fresh, k))
    return {
        "sessions": n_sessions, "generations": n_generations,
        "alpha": alpha, "queries": total, "elapsed_s": elapsed,
        "qps": total / max(elapsed, 1e-12),
        "hit_rate": 1.0 - counts["backend"] / max(total, 1),
        "l1_hit_rate": counts["l1"] / max(total, 1),
        "l2_hit_rate": (counts["l2"] + counts["l2_reuse"]) / max(total, 1),
        "backend_queries": counts["backend"],
        "tier_counts": counts,
        "n_reuse_sampled": len(overlaps),
        "reuse_overlap": float(np.mean(overlaps)) if overlaps else None,
    }


def bench_prefetch(*, widths=(0, 100, 200, 300, 400), n_clusters=8,
                   cluster_iters=10, max_width=400, n_shards=2, k=5, k_c=20,
                   capacity=4096, dtype=None, backend="ref", seed=11) -> dict:
    """Topical-locality prefetch sweep: hit rate vs cache traffic Pareto.

    Builds a dedicated topical world — few dense topics in a low-dim
    subspace, small query noise, misses driven by subtopic jumps, and
    ``norm_jitter=0`` so the Eq. 1 appended coordinate does not inflate
    query-to-centroid distances — clusters it once
    (``repro.core.cluster``), then replays the same conversations at
    several ``prefetch_width`` settings.  Width 0 is the tiered baseline
    (no cluster attached anywhere — exactly the pre-prefetch serving
    stack); each width > 0 attaches the cluster to both the engine
    (miss-time neighbor prefetch folded into the fused insert+query
    launch, claim widened by the triangle inequality) and the shared
    tier (cluster-aware admission).

    Each row reports the combined hit rate alongside the traffic bought:
    docs pushed through the L1 insert launch (and their fp32 wire bytes),
    prefetch issues, and warm hits (cache-served docs that arrived via
    prefetch).  The rows form the Pareto frontier check_regression gates:
    ``hit_gap_best`` (best width > 0 hit rate minus the width-0 baseline)
    must be strictly positive.
    """
    cfg = WorldConfig(n_topics=4, docs_per_topic=300, n_background=600,
                      dim=48, subspace_dim=4, turns=6, n_conversations=6,
                      doc_sigma=0.8, query_sigma=0.05, drift_sigma=0.08,
                      subtopic_prob=0.4, subtopic_sigma=0.45,
                      norm_jitter=0.0, seed=seed)
    world = make_world(cfg)
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32), dtype=dtype)
    cluster = index.cluster(n_clusters, iters=cluster_iters, seed=0,
                            max_width=max_width, backend=backend)
    n_sessions = len(world.conversations)
    streams = _streams(world, index, n_sessions)
    turns = streams[0].shape[0]
    emb_bytes = index.dim * 4            # fp32 wire width per inserted doc
    sids = list(range(n_sessions))
    rows = []
    for width in widths:
        with ShardedRouter(make_shards(index, n_shards),
                           deadline_s=30) as router:
            shared = SharedTier(
                dim=index.dim, n_shards=n_shards,
                capacity=max(8 * k_c, 1024), memo_sim=0.995,
                dtype=dtype, cluster=cluster if width else None)
            engine = BatchedEngine(router, np.asarray(index.dequantized()),
                                   dim=index.dim, n_sessions=n_sessions,
                                   k=k, k_c=k_c, capacity=capacity,
                                   dtype=dtype, backend=backend,
                                   shared=shared,
                                   cluster=cluster if width else None,
                                   prefetch_width=width)
            for s in sids:
                engine.start_session(s)
            counts = {"l1": 0, "l2": 0, "l2_reuse": 0, "backend": 0}
            t0 = time.perf_counter()
            for t in range(turns):
                for turn in engine.answer_batch(
                        sids, [streams[s][t] for s in sids]):
                    counts[turn.tier] += 1
            elapsed = time.perf_counter() - t0
        total = sum(counts.values())
        pf = engine.prefetch_stats()
        rows.append({
            "prefetch_width": width,
            "hit_rate": 1.0 - counts["backend"] / max(total, 1),
            "tier_counts": counts,
            "backend_queries": counts["backend"],
            "prefetch_issued": pf["issued"],
            "prefetch_warm_hits": pf["warm_hits"],
            "insert_traffic_docs": pf["insert_traffic_docs"],
            "insert_traffic_bytes": pf["insert_traffic_docs"] * emb_bytes,
            "queries": total,
            "elapsed_s": elapsed,
        })
        print(f"prefetch w={width:4d}  hit {rows[-1]['hit_rate']:.3f}"
              f"  warm {pf['warm_hits']:4d}  issued {pf['issued']:5d}"
              f"  traffic {pf['insert_traffic_docs']:5d} docs")
    base = rows[0]
    best = max(rows[1:], key=lambda r: r["hit_rate"]) if len(rows) > 1 \
        else base
    return {
        "n_docs": index.n_docs, "dim": index.dim,
        "n_clusters": n_clusters, "max_width": max_width,
        "sessions": n_sessions, "turns": turns, "k": k, "k_c": k_c,
        "capacity": capacity, "rows": rows,
        "baseline_hit_rate": base["hit_rate"],
        "best_width": best["prefetch_width"],
        "hit_gap_best": best["hit_rate"] - base["hit_rate"],
    }


def bench_batched(index, streams, *, n_shards, k, k_c, capacity, dtype=None):
    with ShardedRouter(make_shards(index, n_shards),
                       deadline_s=30) as router:
        engine = BatchedEngine(router, np.asarray(index.dequantized()),
                               dim=index.dim,
                               n_sessions=len(streams), k=k, k_c=k_c,
                               capacity=capacity, dtype=dtype)
        sids = list(range(len(streams)))
        for s in sids:
            engine.start_session(s)
        turns = streams[0].shape[0]
        # warm the jit caches outside the timed region (compile happens once
        # per session-count; a server reuses the compiled wave for its life)
        engine.answer_batch(sids, [streams[s][0] for s in sids])
        for s in sids:
            engine.start_session(s)
        t0 = time.perf_counter()
        wave_best = float("inf")
        for t in range(turns):
            t1 = time.perf_counter()
            engine.answer_batch(sids, [streams[s][t] for s in sids])
            wave_best = min(wave_best, time.perf_counter() - t1)
        elapsed = time.perf_counter() - t0
        hits = engine.hit_rate()   # aggregate across sessions (NaN-safe
        return elapsed, len(streams) * turns, hits, wave_best  # 1-turn)


def wave_traffic(*, n_sessions, dim, capacity, k_c, k, dtype=None):
    """Machine-independent zero-copy metric: trace the kernel-tier cache
    ops of one full miss wave (batched probe + fused insert+query) and sum
    the bytes produced by every NON-Pallas equation — the per-wave overhead
    traffic around the launches.  The pre-padding layout copied the whole
    stacked payload in and out of each launch (>= 2x payload per wave);
    the pre-padded layout moves only wave-sized operands.  Returns
    (wave_moved_bytes, wave_payload_bytes) where the payload is one stacked
    (S, phys_capacity, phys_dim) doc allocation."""
    cfg = CacheConfig(capacity=capacity, dim=dim,
                      store_dtype=quant.resolve_dtype(dtype))
    state = init_batched_cache(cfg, n_sessions)
    psi = jnp.zeros((n_sessions, dim), jnp.float32)
    ids = jnp.zeros((n_sessions, k_c), jnp.int32)
    emb = jnp.zeros((n_sessions, k_c, dim), jnp.float32)
    radius = jnp.zeros((n_sessions,), jnp.float32)
    moved = jaxpr_util.trace_moved_bytes(
        lambda st, p: probe_batched(st, p, cfg.epsilon, backend="interpret",
                                    max_queries=cfg.max_queries),
        state, psi)
    moved += jaxpr_util.trace_moved_bytes(
        lambda st, p, r, e, i: insert_query_batched(
            st, cfg, p, r, e, i, k=k, backend="interpret"),
        state, psi, radius, emb, ids)
    return int(moved), int(state.doc_emb.nbytes)


def _make_engine(index, *, n_sessions, n_shards, k, k_c, capacity, dtype):
    router = ShardedRouter(make_shards(index, n_shards), deadline_s=30)
    return BatchedEngine(router, np.asarray(index.dequantized()),
                         dim=index.dim, n_sessions=n_sessions, k=k, k_c=k_c,
                         capacity=capacity, dtype=dtype)


def _warm_buckets(engine, streams) -> float:
    """Compile every power-of-two wave bucket on both the miss path
    (probe + miss-search + fused insert+query) and the hit path (probe +
    query) so the open-loop measurement never pays an XLA compile, then
    reset all sessions.  Returns the warm full-wave service time (best of
    3 miss waves) — the calibration input for arrival rate and the
    fixed-window baseline."""
    n = engine.n_sessions
    sizes, b = [], 1
    while b < n:
        sizes.append(b)
        b *= 2
    sizes.append(n)
    for size in sizes:
        sids = list(range(size))
        for s in sids:
            engine.start_session(s)
        qs = [streams[s][0] for s in sids]
        engine.answer_batch(sids, qs)   # miss path (insert+query shape)
        engine.answer_batch(sids, qs)   # hit path (query shape)
    svc = float("inf")
    for _ in range(3):
        for s in range(n):
            engine.start_session(s)
        t0 = time.perf_counter()
        engine.answer_batch(list(range(n)), [streams[s][0] for s in range(n)])
        svc = min(svc, time.perf_counter() - t0)
    for s in range(n):
        engine.start_session(s)
    return svc


def _percentiles_ms(xs) -> dict:
    xs = np.asarray(xs, np.float64) * 1e3
    if xs.size == 0:
        return {"count": 0, "p50_ms": None, "p95_ms": None, "p99_ms": None}
    return {"count": int(xs.size),
            "mean_ms": float(xs.mean()),
            "p50_ms": float(np.percentile(xs, 50)),
            "p95_ms": float(np.percentile(xs, 95)),
            "p99_ms": float(np.percentile(xs, 99))}


def _open_loop_once(index, world, *, mode, n_sessions, n_arrivals,
                    arrival_hz, window_s, n_shards, k, k_c, capacity,
                    dtype, seed) -> dict:
    """One open-loop replay: Poisson arrivals at ``arrival_hz`` against a
    ``SessionManager`` in ``mode`` ('continuous' or 'windowed'), with
    session churn (a session whose conversation ends is closed and its key
    reopened on a fresh conversation).  Arrivals follow an absolute
    schedule (sleep-until, no drift), and never wait for earlier turns —
    queue wait lands in the measured latency instead of silently throttling
    the workload."""
    engine = _make_engine(index, n_sessions=n_sessions, n_shards=n_shards,
                          k=k, k_c=k_c, capacity=capacity, dtype=dtype)
    mgr_kwargs = (dict(window_s=0.0, adaptive=True, overlap=True)
                  if mode == "continuous" else
                  dict(window_s=window_s, adaptive=False, overlap=False))
    rng = np.random.default_rng(seed)
    convs = world.conversations
    conv_len = convs[0].queries.shape[0]
    next_conv = n_sessions          # global cursor for churned sessions

    def stream_for(conv_idx):
        return np.asarray(index.transform_queries(jnp.asarray(
            convs[conv_idx % len(convs)].queries, jnp.float32)))

    streams = {key: stream_for(key) for key in range(n_sessions)}
    ptr = {key: 0 for key in range(n_sessions)}
    churns = 0
    futures = []
    try:
        with SessionManager(engine, max_batch=n_sessions,
                            **mgr_kwargs) as mgr:
            for key in range(n_sessions):
                mgr.open(key)
            gaps = rng.exponential(1.0 / arrival_hz, size=n_arrivals)
            sched = np.cumsum(gaps) + time.perf_counter()
            for i in range(n_arrivals):
                now = time.perf_counter()
                if sched[i] > now:
                    time.sleep(sched[i] - now)
                key = int(rng.integers(n_sessions))
                if ptr[key] >= conv_len:
                    # churn: this conversation is over — drain + recycle
                    # the slot, open the key on a fresh conversation
                    mgr.close(key)
                    mgr.open(key)
                    streams[key] = stream_for(next_conv)
                    ptr[key] = 0
                    next_conv += 1
                    churns += 1
                futures.append(mgr.submit(key, streams[key][ptr[key]]))
                ptr[key] += 1
            mgr.flush()
            turns = [f.result(timeout=60) for f in futures]
            summary = mgr.telemetry.summary()
    finally:
        engine.router.close()
    totals = [t.latency_s for t in turns]
    waits = [t.queue_wait_s for t in turns]
    rec = {
        "mode": mode,
        "arrivals": n_arrivals,
        "arrival_hz": arrival_hz,
        "churns": churns,
        "hit_rate": float(np.mean([t.hit for t in turns])),
        "total": _percentiles_ms(totals),
        "queue_wait": _percentiles_ms(waits),
        "tiers": {tier: _percentiles_ms(
            [t.latency_s for t in turns if t.tier == tier])
            for tier in sorted({t.tier for t in turns})},
        "waves": summary["waves"],
        "mean_wave": summary["wave_size"]["mean"],
    }
    if mode == "windowed":
        rec["window_ms"] = window_s * 1e3
    return rec


def bench_open_loop(index, world, *, n_sessions, n_arrivals, load=0.5,
                    n_shards=4, k=10, k_c=100, capacity=None, dtype=None,
                    repeats=2, seed=17) -> dict:
    """Continuous scheduler vs fixed-window admission under identical
    open-loop Poisson traffic.

    Calibration keeps the record machine-independent in shape: the warm
    full-wave service time ``svc`` sets both the arrival rate
    (``load / svc`` — a fixed multiple of the wave rate, not a fixed Hz;
    small enough that neither mode saturates, so the A/B measures
    admission policy rather than queue buildup) and the fixed-window
    baseline's window (``4 x svc``, floored at 4 ms — the historical
    fixed-window default regime).  Each mode runs ``repeats`` times and
    keeps its lowest-p99 run (wall-clock on shared hosts is noisy; the
    minimum is each policy's least-contended estimate).  The gated
    headline is ``p99_improvement``: windowed p99 over continuous p99,
    which the continuous scheduler wins by not holding arrivals hostage
    to the window timer.
    """
    capacity = capacity or 4 * k_c
    warm_engine = _make_engine(index, n_sessions=n_sessions,
                               n_shards=n_shards, k=k, k_c=k_c,
                               capacity=capacity, dtype=dtype)
    warm_streams = _streams(world, index, n_sessions)
    svc = _warm_buckets(warm_engine, warm_streams)
    arrival_hz = load / max(svc, 1e-5)
    window_s = max(4.0 * svc, 0.004)
    kwargs = dict(n_sessions=n_sessions, n_arrivals=n_arrivals,
                  arrival_hz=arrival_hz, window_s=window_s,
                  n_shards=n_shards, k=k, k_c=k_c, capacity=capacity,
                  dtype=dtype)
    def best(mode):
        runs = [_open_loop_once(index, world, mode=mode, seed=seed + r,
                                **kwargs) for r in range(repeats)]
        return min(runs, key=lambda r: r["total"]["p99_ms"])
    continuous = best("continuous")
    windowed = best("windowed")
    improvement = (windowed["total"]["p99_ms"]
                   / max(continuous["total"]["p99_ms"], 1e-9))
    rec = {
        "sessions": n_sessions,
        "load": load,
        "wave_service_ms": svc * 1e3,
        "arrival_hz": arrival_hz,
        "window_ms": window_s * 1e3,
        "continuous": continuous,
        "windowed": windowed,
        "p99_improvement": improvement,
    }
    print(f"open-loop({n_sessions} sessions, {arrival_hz:.0f}/s): "
          f"continuous p99 {continuous['total']['p99_ms']:.1f}ms "
          f"(wait p99 {continuous['queue_wait']['p99_ms']:.1f}ms) | "
          f"windowed p99 {windowed['total']['p99_ms']:.1f}ms "
          f"(window {window_s * 1e3:.1f}ms) | "
          f"p99 improvement {improvement:.2f}x")
    return rec


def run_open_loop(*, smoke=False, dtype=None,
                  out_path="BENCH_serve.json") -> dict:
    """Entry point for ``--open-loop``: builds the world, runs the A/B
    open-loop measurement, and merge-writes it under ``open_loop`` (nested
    in ``smoke`` for smoke runs, the schema check_regression gates)."""
    if smoke:
        cfg = WorldConfig(n_topics=4, docs_per_topic=200, n_background=1000,
                          dim=64, subspace_dim=8, turns=3, n_conversations=8,
                          doc_sigma=0.6, query_sigma=0.12, drift_sigma=0.16,
                          subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
        n_sessions, n_arrivals, k_c = 8, 240, 50
    else:
        cfg = WorldConfig(n_topics=8, docs_per_topic=800, n_background=4000,
                          dim=128, subspace_dim=8, turns=4,
                          n_conversations=16, doc_sigma=0.6,
                          query_sigma=0.12, drift_sigma=0.16,
                          subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
        n_sessions, n_arrivals, k_c = 64, 2000, 100
    world = make_world(cfg)
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32), dtype=dtype)
    rec = bench_open_loop(index, world, n_sessions=n_sessions,
                          n_arrivals=n_arrivals, k_c=k_c, dtype=dtype)
    rec["timestamp"] = time.time()
    merge_json(out_path,
               {"smoke": {"open_loop": rec}} if smoke
               else {"open_loop": rec})
    return rec


def _jittered_streams(world, index, n_sessions, rng, jitter):
    """Per-session streams with fresh per-call query jitter, so replaying
    the same conversations across chaos rounds yields near-duplicate (not
    identical) queries — semantic reuse stays possible, trivial
    memoization does not hide the back end from the fault schedule."""
    convs = world.conversations
    out = []
    for s in range(n_sessions):
        raw = np.asarray(convs[s % len(convs)].queries)
        raw = raw + jitter * rng.standard_normal(raw.shape)
        out.append(np.asarray(index.transform_queries(
            jnp.asarray(raw, jnp.float32))))
    return out


def bench_chaos(index, world, *, n_sessions=8, rounds=10, n_shards=4,
                k=10, k_c=50, capacity=None, dtype=None, deadline_s=2.0,
                spike_s=0.02, jitter=0.1, seed=23) -> dict:
    """Replay the committed chaos schedule against the resilient stack.

    ``rounds`` cohorts of ``n_sessions`` sessions each replay their
    conversations (with per-round query jitter) through a ``BatchedEngine``
    whose router fleet is wrapped by ``repro.serve.faults.chaos_plan``:
    shard 0 flaps through two outage windows, shard 1 spikes latency past
    the hedge trigger, shard 2 returns corrupt answers rotating through
    every corruption mode, shards 3+ stay healthy.  Breaker knobs are
    sized so the flapping shard's breaker opens, half-open probes, and
    re-closes *within the run* — the transition counts land in the gated
    record.

    The emitted record is the chaos gate's input: ``warm_availability``
    (answered fraction of turns whose session already had a turn this
    round; >= 0.99), ``corrupt_served`` (answers merged with
    out-of-corpus ids or non-finite scores; must be 0 — the validator's
    whole job), breaker open/close counts (>= 1 each), the rank overlap
    of degraded answers vs a clean fleet's fresh retrieval, and tail
    latency under faults.
    """
    capacity = capacity or 4 * k_c
    rng = np.random.default_rng(seed)
    sids = list(range(n_sessions))
    plan = chaos_plan(n_shards, seed=seed, spike_s=spike_s)
    telemetry = ServeTelemetry()
    total = answered = warm_total = warm_answered = 0
    corrupt = degraded_turns = 0
    turn_times: list = []
    degraded_samples: list = []
    with ShardedRouter(plan.wrap(make_shards(index, n_shards)),
                       deadline_s=deadline_s, hedge_after_s=spike_s / 2,
                       n_docs=index.n_docs, max_retries=1,
                       backoff_base_s=0.002, breaker_window=8,
                       breaker_fail_rate=0.5, breaker_min_calls=2,
                       breaker_cooldown_s=0.25,
                       telemetry=telemetry) as router:
        shared = SharedTier(dim=index.dim, n_shards=n_shards,
                            capacity=max(8 * k_c, 1024), memo_sim=0.995,
                            ttl_waves=3, dtype=dtype)
        engine = BatchedEngine(router, np.asarray(index.dequantized()),
                               dim=index.dim, n_sessions=n_sessions, k=k,
                               k_c=k_c, capacity=capacity, dtype=dtype,
                               shared=shared, telemetry=telemetry,
                               validate_every=4)
        t_run = time.perf_counter()
        for _r in range(rounds):
            streams = _jittered_streams(world, index, n_sessions, rng,
                                        jitter)
            for s in sids:
                engine.start_session(s)
            for t in range(streams[0].shape[0]):
                qs = [streams[s][t] for s in sids]
                t0 = time.perf_counter()
                try:
                    out = engine.answer_batch(sids, qs)
                except TimeoutError:      # whole wave fenced, caches empty
                    out = [None] * len(sids)
                dt = time.perf_counter() - t0
                if _r > 0:     # round 0 pays the XLA wave compiles; the
                    # tail under FAULTS is the record, not compile noise
                    turn_times.extend([dt] * len(sids))
                for s, turn in zip(sids, out):
                    total += 1
                    if t > 0:
                        warm_total += 1
                    if turn is None or isinstance(turn, Exception):
                        continue
                    answered += 1
                    if t > 0:
                        warm_answered += 1
                    row_ids = np.asarray(turn.ids)
                    row_scores = np.asarray(turn.scores)
                    if row_ids.size and (
                            (row_ids < 0).any()
                            or (row_ids >= index.n_docs).any()
                            or not np.isfinite(row_scores).all()):
                        corrupt += 1
                    if turn.degraded:
                        degraded_turns += 1
                        if len(degraded_samples) < 64 and row_ids.size:
                            degraded_samples.append((qs[s], row_ids))
        elapsed = time.perf_counter() - t_run
        stats = router.stats
        health = router.shard_health()
    # quality of degraded answers: top-k overlap vs a CLEAN fleet's fresh
    # retrieval of the same query (missing-shard merges and stale serves
    # should stay mostly right, not confidently wrong)
    overlaps = []
    with ShardedRouter(make_shards(index, n_shards),
                       deadline_s=30) as clean:
        for psi_q, served in degraded_samples:
            ans, _ = clean.search(np.asarray(psi_q)[None], k_c)
            fresh = ans.ids[0][ans.ids[0] >= 0]
            overlaps.append(_rank_overlap(
                served, fresh, min(k, int(served.size))))
    rec = {
        "sessions": n_sessions, "rounds": rounds, "n_shards": n_shards,
        "turns_per_round": int(
            world.conversations[0].queries.shape[0]),
        "k": k, "k_c": k_c, "seed": seed, "elapsed_s": elapsed,
        "total_turns": total, "answered_turns": answered,
        "availability": answered / max(total, 1),
        "warm_availability": warm_answered / max(warm_total, 1),
        "corrupt_served": corrupt,
        "degraded_turns": degraded_turns,
        "n_degraded_sampled": len(overlaps),
        "degraded_overlap": float(np.mean(overlaps)) if overlaps else None,
        "latency": _percentiles_ms(turn_times),
        "breaker_opens": stats.breaker_opens,
        "breaker_closes": stats.breaker_closes,
        "breaker_skips": stats.breaker_skips,
        "rejected_answers": stats.rejected,
        "retries": stats.retries, "hedges": stats.hedges,
        "failures": stats.failures, "timeouts": stats.timeouts,
        "searches": stats.calls, "shed": stats.shed,
        "stale_served": shared.n_stale_served,
        "quarantined": engine.quarantined,
        "faults": telemetry.summary()["faults"],
        "injected_calls": plan.calls(),
        "injected_faults": [w.faults for w in plan.wrapped],
        "shard_health": health,
    }
    print(f"chaos({n_sessions} sessions x {rounds} rounds): "
          f"avail {rec['availability']:.4f} "
          f"(warm {rec['warm_availability']:.4f}) | corrupt served "
          f"{corrupt} | rejected {stats.rejected} | breaker "
          f"open/close {stats.breaker_opens}/{stats.breaker_closes} | "
          f"degraded {degraded_turns} overlap {rec['degraded_overlap']} | "
          f"p99 {rec['latency']['p99_ms']:.1f}ms")
    return rec


def run_chaos(*, smoke=False, dtype=None,
              out_path="BENCH_serve.json") -> dict:
    """Entry point for ``--chaos``: builds the world, replays the committed
    chaos schedule, and merge-writes the record under ``chaos`` (nested in
    ``smoke`` for smoke runs — the schema check_regression gates)."""
    if smoke:
        cfg = WorldConfig(n_topics=4, docs_per_topic=200, n_background=1000,
                          dim=64, subspace_dim=8, turns=3, n_conversations=8,
                          doc_sigma=0.6, query_sigma=0.12, drift_sigma=0.16,
                          subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
        kwargs = dict(n_sessions=8, rounds=10, k_c=50)
    else:
        cfg = WorldConfig(n_topics=8, docs_per_topic=800, n_background=4000,
                          dim=128, subspace_dim=8, turns=4,
                          n_conversations=16, doc_sigma=0.6,
                          query_sigma=0.12, drift_sigma=0.16,
                          subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
        kwargs = dict(n_sessions=16, rounds=16, k_c=100)
    world = make_world(cfg)
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32), dtype=dtype)
    rec = bench_chaos(index, world, dtype=dtype, **kwargs)
    rec["timestamp"] = time.time()
    merge_json(out_path,
               {"smoke": {"chaos": rec}} if smoke else {"chaos": rec})
    return rec


def run(session_counts=(64, 128, 256, 512), *, turns=4, n_shards=4,
        k=10, k_c=100, repeats=3, world_cfg=None, dtype=None, smoke=False,
        out_path="BENCH_serve.json") -> dict:
    world = make_world(world_cfg or WorldConfig(
        n_topics=8, docs_per_topic=800, n_background=4000, dim=128,
        subspace_dim=8, turns=turns, n_conversations=16, doc_sigma=0.6,
        query_sigma=0.12, drift_sigma=0.16, subtopic_prob=0.35,
        subtopic_sigma=0.75, seed=7))
    index = MetricIndex(jnp.asarray(world.doc_emb, jnp.float32), dtype=dtype)
    capacity = 4 * k_c
    rows = []
    for n_sessions in session_counts:
        streams = _streams(world, index, n_sessions)
        # best-of-N: wall-clock on a shared host is noisy; the minimum is
        # the least-contended estimate of each path's real cost
        t_seq, t_bat, t_wave = float("inf"), float("inf"), float("inf")
        for _ in range(repeats):
            t, n_q, hit_seq = bench_sequential(
                index, streams, n_shards=n_shards, k=k, k_c=k_c,
                capacity=capacity, dtype=dtype)
            t_seq = min(t_seq, t)
            t, _, hit_bat, wave_best = bench_batched(
                index, streams, n_shards=n_shards, k=k, k_c=k_c,
                capacity=capacity, dtype=dtype)
            t_bat = min(t_bat, t)
            t_wave = min(t_wave, wave_best)
        moved, payload = wave_traffic(
            n_sessions=n_sessions, dim=index.dim, capacity=capacity,
            k_c=k_c, k=k, dtype=dtype)
        row = {
            "sessions": n_sessions, "turns": int(streams[0].shape[0]),
            "queries": n_q,
            "sequential_s": t_seq, "batched_s": t_bat,
            "sequential_qps": n_q / t_seq, "batched_qps": n_q / t_bat,
            "speedup": t_seq / max(t_bat, 1e-12),
            "hit_rate_sequential": hit_seq, "hit_rate_batched": hit_bat,
            # zero-copy columns: best-of-N single-wave latency, and the
            # traced non-launch traffic of one miss wave vs one stacked
            # payload (machine-independent; gated by check_regression)
            "batched_wave_best_s": t_wave,
            "wave_moved_bytes": moved,
            "wave_payload_bytes": payload,
        }
        rows.append(row)
        print(f"sessions={n_sessions:4d}  sequential {row['sequential_qps']:8.1f} q/s"
              f"  batched {row['batched_qps']:8.1f} q/s"
              f"  speedup {row['speedup']:.1f}x"
              f"  wave {1e3 * t_wave:.1f}ms"
              f"  moved/payload {moved / max(payload, 1):.2f}x")
    # Zipfian multi-user workload: the same skewed traffic served with the
    # shared L2 tier attached and private-cache-only; the gap between the
    # two combined hit rates is the tier's raison d'etre (gated by
    # check_regression alongside the reuse-quality overlap floor)
    zipf_sessions = min(max(session_counts), 8 if smoke else 64)
    zipf_kwargs = dict(n_sessions=zipf_sessions, n_generations=3,
                       n_shards=n_shards, k=k, k_c=k_c,
                       capacity=capacity, dtype=dtype)
    tiered = bench_zipf(index, world, with_shared=True, **zipf_kwargs)
    l1only = bench_zipf(index, world, with_shared=False, **zipf_kwargs)
    zipf = dict(tiered)
    zipf["l1_only_hit_rate"] = l1only["hit_rate"]
    zipf["hit_gap"] = tiered["hit_rate"] - l1only["hit_rate"]
    zipf["backend_queries_saved"] = (l1only["backend_queries"]
                                     - tiered["backend_queries"])
    print(f"zipf({zipf_sessions} sessions x {zipf['generations']} gens)"
          f"  l1-only hit {zipf['l1_only_hit_rate']:.3f}"
          f"  tiered hit {zipf['hit_rate']:.3f}"
          f"  (l1 {zipf['l1_hit_rate']:.3f} + l2 {zipf['l2_hit_rate']:.3f})"
          f"  backend saved {zipf['backend_queries_saved']}"
          f"  reuse overlap {zipf['reuse_overlap']}")
    # Topical-locality prefetch sweep: its own world (norm_jitter=0, dense
    # topics) so the triangle-inequality claim widening has a regime to
    # win in; width 0 is the pre-prefetch tiered stack, the gated Pareto
    # headline is hit_gap_best > 0 (strictly)
    prefetch = bench_prefetch(dtype=dtype)
    print(f"prefetch sweep: baseline hit {prefetch['baseline_hit_rate']:.3f}"
          f"  best hit {prefetch['baseline_hit_rate'] + prefetch['hit_gap_best']:.3f}"
          f" @ width {prefetch['best_width']}"
          f"  gap {prefetch['hit_gap_best']:+.3f}")
    record = {"n_docs": index.n_docs, "dim": world.cfg.dim, "k": k,
              "k_c": k_c, "n_shards": n_shards, "dtype": index.dtype,
              "rows": rows, "zipf": zipf, "prefetch": prefetch,
              "timestamp": time.time()}
    # merge-write so full runs and smoke runs co-own one file: the smoke
    # record nests under "smoke" (the committed-baseline schema
    # benchmarks/check_regression.py reads) and neither overwrites the other
    merge_json(out_path, {"smoke": record} if smoke else record)
    return record


def _deep_merge(dst: dict, src: dict) -> dict:
    """Recursively merge ``src`` into ``dst`` (nested dicts merge key-wise,
    anything else overwrites) so e.g. ``--smoke --open-loop`` extends the
    existing ``smoke`` record instead of replacing it."""
    for key, val in src.items():
        if isinstance(val, dict) and isinstance(dst.get(key), dict):
            _deep_merge(dst[key], val)
        else:
            dst[key] = val
    return dst


def merge_json(path: str, updates: dict) -> None:
    """Deep-merge ``updates`` into a JSON object file, preserving other
    keys (standalone sibling of benchmarks.kernel_bench.merge_json: this
    module must run as a plain script, where sibling imports don't
    resolve)."""
    rec = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                rec = json.load(f)
        except (json.JSONDecodeError, OSError):
            rec = {}
    if not isinstance(rec, dict):
        rec = {}
    _deep_merge(rec, updates)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI (8 sessions, tiny world)")
    ap.add_argument("--open-loop", action="store_true",
                    help="open-loop Poisson tail-latency A/B (continuous "
                         "scheduler vs fixed-window admission) instead of "
                         "the closed-loop throughput sweep")
    ap.add_argument("--chaos", action="store_true",
                    help="replay the committed fault schedule "
                         "(repro.serve.faults.chaos_plan) and emit the "
                         "availability / corruption / breaker record the "
                         "chaos gate checks")
    ap.add_argument("--dtype", default=None,
                    help="corpus + cache storage format (fp32/bf16/int8; "
                         "default follows REPRO_CORPUS_DTYPE)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.chaos:
        run_chaos(smoke=args.smoke, dtype=args.dtype, out_path=args.out)
    elif args.open_loop:
        run_open_loop(smoke=args.smoke, dtype=args.dtype, out_path=args.out)
    elif args.smoke:
        cfg = WorldConfig(n_topics=4, docs_per_topic=200, n_background=1000,
                          dim=64, subspace_dim=8, turns=3, n_conversations=8,
                          doc_sigma=0.6, query_sigma=0.12, drift_sigma=0.16,
                          subtopic_prob=0.35, subtopic_sigma=0.75, seed=7)
        run((8,), turns=3, k_c=50, repeats=1, world_cfg=cfg, dtype=args.dtype,
            smoke=True, out_path=args.out)
    else:
        run(dtype=args.dtype, out_path=args.out)


if __name__ == "__main__":
    main()
