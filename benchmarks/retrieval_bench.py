"""Back-end retrieval benchmark: exact_nn vs chunked_nn vs sharded retrieval
at 1M synthetic docs — the perf trajectory anchor for the distributed index.

Writes ``BENCH_retrieval.json`` and returns rows for the harness CSV.

Run as its own entry point (``python -m benchmarks.retrieval_bench``): the
sharded rows need a multi-device topology, and forcing it inside the main
harness process would silently re-baseline every other table's timings —
``benchmarks.run`` therefore shells out to this module.
"""

from __future__ import annotations

from repro.launch.hostdevices import ensure_host_devices

ensure_host_devices(8)

import json  # noqa: E402
import time  # noqa: E402

import jax         # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from benchmarks.common import timed
from repro.core import embedding as emb
from repro.core.metric_index import chunked_nn, exact_nn
from repro.dist import retrieval as dr

N_DOCS = 1 << 20
DIM = 64
N_QUERIES = 16
K = 100
CHUNK = 4096


def _make_corpus(n=N_DOCS, dim=DIM, nq=N_QUERIES, seed=0):
    rng = np.random.default_rng(seed)
    docs, _ = emb.transform_documents(
        jnp.asarray(rng.standard_normal((n, dim), ).astype(np.float32)))
    queries = emb.transform_queries(
        jnp.asarray(rng.standard_normal((nq, dim)).astype(np.float32)))
    ids = jnp.arange(n, dtype=jnp.int32)
    return docs, ids, queries


def run(out_path: str = "BENCH_retrieval.json") -> dict:
    docs, ids, queries = _make_corpus()
    n_dev = jax.device_count()

    t_exact, ref = timed(lambda: exact_nn(docs, ids, queries, K))
    t_chunk, res_c = timed(
        lambda: chunked_nn(docs, ids, queries, K, chunk=CHUNK))
    t_shard, res_s = timed(
        lambda: dr.sharded_nn(docs, ids, queries, K, chunk=CHUNK))

    identical = bool(
        np.array_equal(np.asarray(ref.ids), np.asarray(res_c.ids))
        and np.array_equal(np.asarray(ref.ids), np.asarray(res_s.ids)))

    record = {
        "n_docs": N_DOCS, "dim": DIM, "n_queries": N_QUERIES, "k": K,
        "chunk": CHUNK, "n_devices": n_dev,
        "exact_us": 1e6 * t_exact,
        "chunked_us": 1e6 * t_chunk,
        "sharded_us": 1e6 * t_shard,
        "sharded_speedup_vs_chunked": t_chunk / max(t_shard, 1e-12),
        "rankings_identical": identical,
        "timestamp": time.time(),
    }
    # merge-update: keep other sections (e.g. kernel_bench's "kernels" rows)
    from benchmarks.kernel_bench import merge_json
    merge_json(out_path, record)
    return record


if __name__ == "__main__":
    print(json.dumps(run()))
