"""Paper Table 1: retrieval effectiveness + cov_10 + hit rate for
no-caching / static-CACHE / dynamic-CACHE over the k_c sweep.

Validation targets from the paper (qualitative, synthetic workload):
  * static-CACHE degrades every metric, improving with k_c; cov10 low.
  * dynamic-CACHE is statistically indistinguishable from no-caching
    (p >= 0.01) on nDCG@3/P@k with cov10 >= ~0.9 and hit rate 55-75%.
"""

from __future__ import annotations

from benchmarks import common as C


def run(world=None, index=None):
    world = world or C.make_world(C.DEFAULT_WORLD)
    index = index or C.build_index(world)
    base = C.evaluate_policy(world, index, "none", k_c=C.KC_SWEEP[0])
    rows = [base]
    for policy in ("static", "dynamic"):
        for k_c in C.KC_SWEEP:
            row = C.evaluate_policy(world, index, policy, k_c=k_c)
            rows.append(C.attach_significance(row, base))
    return rows


def main():
    rows = run()
    hdr = (f"{'policy':>10} {'k_c':>5} {'MAP@200':>8} {'MRR@200':>8} "
           f"{'nDCG@3':>7} {'P@1':>6} {'P@3':>6} {'cov10':>6} {'hit%':>7} "
           f"{'p(MAP)':>7} {'p(nDCG)':>8} {'maxdocs':>8}")
    print(hdr)
    for r in rows:
        print(f"{r.policy:>10} {r.k_c:>5} {r.map200:8.3f} {r.mrr200:8.3f} "
              f"{r.ndcg3:7.3f} {r.p1:6.3f} {r.p3:6.3f} {r.cov10:6.2f} "
              f"{100 * r.hit_rate:7.2f} {r.p_map:7.3f} {r.p_ndcg:8.3f} "
              f"{r.max_cache_docs:>8}")
    return rows


if __name__ == "__main__":
    main()
